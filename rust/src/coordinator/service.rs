//! Real-mode CACS service: the Fig 1 managers over real threads, real
//! storage and real (PJRT-executed) workloads.
//!
//! * Application Manager — [`CacsService::submit`] / [`CacsService::restart`]
//!   / [`CacsService::delete`], enforcing the Fig 2 lifecycle.
//! * Cloud Manager — in real mode the "virtual cluster" is the
//!   application host thread ([`super::appthread`]); provisioning is
//!   construction of the workload (PJRT artifact compilation plays the
//!   role of VM provisioning).
//! * Checkpoint Manager — stateless over any [`ObjectStore`] (§6.2),
//!   including streaming image upload/download; cross-CACS migration is
//!   a first-class operation (§5.3) driven by [`super::migrate`] over
//!   the `begin/record/abort/complete` plumbing here.
//! * Monitoring Manager — one §6.3 broadcast tree per application
//!   ([`crate::coordinator::healthplane::AppMonitor`]), leaf hooks wired
//!   to the per-proc health flags through a bounded non-blocking probe
//!   of the host thread.  [`CacsService::monitor_round`] fans every
//!   application's heartbeat out concurrently under one whole-round
//!   deadline and drives both §6.3 recovery cases off the structured
//!   [`HealthReport`]s: unreachable hosts are re-provisioned and
//!   restored from the last image (case 1), unhealthy processes restart
//!   in place (case 2).  Apps parked in ERROR with a usable checkpoint
//!   are picked up via the §5.3 passive recovery path (ERROR →
//!   RESTARTING).  A wedged host thread is detected within the
//!   heartbeat budget — never the 120 s data-plane timeout — and a
//!   construct-failed app reports all procs unreachable, not healthy.

use crate::coordinator::adaptive::AdaptiveCkptConfig;
use crate::coordinator::appthread::{
    self, ActorPool, AppEvent, AppFactory, AppHandle, PoolStats, CTRL_PROBE_TIMEOUT,
};
use crate::coordinator::db::Db;
use crate::coordinator::healthplane::{heartbeat_pool, AppMonitor};
use crate::coordinator::lifecycle::AppState;
use crate::coordinator::scheduler;
use crate::coordinator::types::{AppRecord, Asr, CkptRecord, HealthStatus, WorkloadSpec};
use crate::dckpt::delta::DeltaPolicy;
use crate::dckpt::service as ckptsvc;
use crate::dckpt::{CounterApp, DistributedApp};
use crate::monitor::{HealthProbe, HealthReport};
use crate::runtime::Engine;
use crate::storage::ObjectStore;
use crate::util::ids::{AppId, CkptId, IdGen};
use crate::util::json::Json;
use crate::workloads::{dmtcp1::Dmtcp1App, lu, ns3};
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::io::{Read, Write};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

/// Service configuration.
#[derive(Clone)]
pub struct ServiceConfig {
    /// AOT artifacts directory; enables the PJRT backend when the
    /// matching artifact exists (falls back to native otherwise).
    pub artifacts_dir: Option<PathBuf>,
    /// Throttle between workload steps (zero = run hot).
    pub step_interval: Duration,
    /// Pad images with the modelled DMTCP runtime overhead.
    pub with_runtime_overhead: bool,
    /// Health-monitoring period; None disables the monitor thread.
    pub monitor_period: Option<Duration>,
    /// Recover automatically from the latest checkpoint on failure.
    pub auto_recover: bool,
    /// Per-hop share of the §6.3 heartbeat deadline budget: one app's
    /// tree answers within ≈ `heartbeat_hop × (height + 2)`.
    pub heartbeat_hop: Duration,
    /// Broadcast-tree arity (2 per the paper; wider = flatter tree,
    /// fewer hops, more fan-out per daemon).  Values < 2 are clamped.
    pub heartbeat_arity: usize,
    /// Dirty-chunk delta engine knobs (chunk size, dirty-ratio ceiling,
    /// chain-length bound) threaded into every app's host thread.
    pub delta: DeltaPolicy,
    /// Retention for periodic cuts: keep the chains rooted at the last
    /// `ckpt_keep` full images, prune everything older after each
    /// successful periodic checkpoint.  0 disables pruning.
    pub ckpt_keep: usize,
    /// Young/Daly adaptive checkpoint intervals: when enabled, each
    /// successful periodic cut re-derives the app's `ckpt_period` from
    /// the measured cut cost and observed MTBF (§5.2 mode 2 stays the
    /// fallback until the controller has data).
    pub adaptive: AdaptiveCkptConfig,
    /// Actor-pool width (OS threads multiplexing every app actor);
    /// 0 = derive from available parallelism.  Apps scale independently
    /// of thread count: 1k apps on 8 workers is the designed regime.
    pub actor_workers: usize,
    /// First app id this instance allocates, minus one.  Federated
    /// deployments give each shard a disjoint base (e.g. `k × 10⁹`) so
    /// ids allocated independently never collide at the router.
    pub id_base: u64,
    /// Build a §6.3 broadcast tree (and its per-node daemon threads)
    /// per app.  Disable for huge fleets driven without the monitor
    /// (e.g. the 1k-app scale bench): health endpoints then serve
    /// "no evidence" verdicts and `monitor_round` is a no-op.
    pub health_trees: bool,
    /// §2.2 use case 4 oversubscription: how many apps may hold a live
    /// host slot at once.  0 = unlimited (the scheduler is off, the
    /// pre-existing behavior).  When the occupied count exceeds this,
    /// the [`scheduler`](crate::coordinator::scheduler) swaps the
    /// lowest-priority victims out (checkpoint → release slot → park
    /// the image chain cold) and swaps them back in as slots free up.
    pub capacity_slots: usize,
    /// Test seam: sleep this long in the off-lock spawn phase of
    /// submit, proving the service lock is not held across provisioning.
    #[cfg(test)]
    pub(crate) submit_spawn_delay: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            artifacts_dir: None,
            step_interval: Duration::from_millis(1),
            with_runtime_overhead: false,
            monitor_period: Some(Duration::from_millis(200)),
            auto_recover: true,
            heartbeat_hop: Duration::from_millis(75),
            heartbeat_arity: 2,
            delta: DeltaPolicy::default(),
            ckpt_keep: 2,
            adaptive: AdaptiveCkptConfig::default(),
            actor_workers: 0,
            id_base: 0,
            health_trees: true,
            capacity_slots: 0,
            #[cfg(test)]
            submit_spawn_delay: Duration::ZERO,
        }
    }
}

/// Patient direct-probe timeout the monitor uses to confirm a failure
/// before destructive recovery: long enough for an app whose step
/// barrier is slow (the tree probe is hop-bounded and errs fast), far
/// shorter than the 120 s data-plane timeout.  Apps stepping slower
/// than this per barrier must raise `heartbeat_hop` / slow the monitor.
const RECOVERY_CONFIRM_TIMEOUT: Duration = Duration::from_secs(1);

/// Why a migration could not start (the REST layer maps these to
/// 404 / 409 — anything later in the flow is a transfer failure).
#[derive(Debug)]
pub enum MigrateStartError {
    /// No such coordinator (404).
    UnknownCoordinator,
    /// The lifecycle refuses `RUNNING → MIGRATING` right now, e.g. a
    /// checkpoint or another migration is in flight (409).
    BadState(AppState),
    /// The record exists but its host thread is gone (409 — recovery
    /// owns the app until it is RUNNING again).
    NoAppThread,
}

impl std::fmt::Display for MigrateStartError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MigrateStartError::UnknownCoordinator => write!(f, "unknown coordinator"),
            MigrateStartError::BadState(s) => write!(f, "cannot migrate in state {s}"),
            MigrateStartError::NoAppThread => write!(f, "no app thread"),
        }
    }
}

impl std::error::Error for MigrateStartError {}

/// Everything the migration orchestrator needs after claiming the app:
/// the host-thread handle (for quiesce + checkpoint off-lock), the ASR
/// to clone onto the destination, and the reserved checkpoint seq.
pub(crate) struct MigrationTicket {
    pub handle: Arc<AppHandle>,
    pub seq: u64,
    pub asr: Asr,
    pub with_overhead: bool,
}

/// One registry shard.  App state proper lives inside the actors; a
/// shard only tracks the record database, the actor handles and the
/// recovery/monitor bookkeeping for the apps hashed onto it.
struct Inner {
    db: Db,
    // Arc so bulk operations (checkpoint/restore image transfers, health
    // round-trips) can clone the handle out and run WITHOUT any registry
    // lock — the Monitoring Manager must stay live while images move
    handles: BTreeMap<AppId, Arc<AppHandle>>,
    // one §6.3 broadcast tree per application; outlives the app's actor
    // (kill_vm drops the handle, the tree then reports the procs
    // unreachable) and is rewired to the replacement host on recovery
    monitors: BTreeMap<AppId, Arc<AppMonitor>>,
    // apps a monitor round has claimed for recovery: a concurrent round
    // (or a round racing the tail of this one) must not double-recover
    recovering: BTreeSet<AppId>,
    // SWAPPED_OUT apps hashed onto this shard → the seq of the cut they
    // were parked at; swap-in restores exactly this cut, so the victim
    // resumes at the iteration it was preempted at
    swapped: BTreeMap<AppId, u64>,
}

impl Inner {
    fn empty() -> Inner {
        Inner {
            db: Db::new(),
            handles: BTreeMap::new(),
            monitors: BTreeMap::new(),
            recovering: BTreeSet::new(),
            swapped: BTreeMap::new(),
        }
    }
}

/// Registry shard count.  Ids are allocated round-robin so consecutive
/// submits land on different shards; 16 keeps lock contention negligible
/// at 10k apps while cross-shard scans stay cheap.
const N_SHARDS: usize = 16;

/// The service.  Share via `Arc`; [`start_monitor`](CacsService::start_monitor)
/// runs the Monitoring Manager until the service drops.
pub struct CacsService {
    cfg: ServiceConfig,
    store: Arc<dyn ObjectStore>,
    /// Present when the store is a [`TieredStore`]: the scheduler then
    /// demotes a swapped-out app's image chain to the cold tier and
    /// promotes it back on swap-in.  `store` is the same object as
    /// `tiers` (the trait-object view), so every existing checkpoint /
    /// restore / delete path routes through the tiers unchanged.
    tiers: Option<Arc<crate::storage::tiered::TieredStore>>,
    /// Service-wide id allocator (ids span shards, so allocation cannot
    /// live inside any one shard's `Db`).
    ids: IdGen,
    /// Sharded registry: per-app operations lock only `shards[id % N]`,
    /// so checkpoints, health rounds, migration and REST on different
    /// apps no longer serialize against each other.  Declared before
    /// `actors` so every `AppHandle` drops before the worker pool does.
    shards: Vec<Mutex<Inner>>,
    /// Bounded worker pool multiplexing every app actor; replaces the
    /// old one-OS-thread-per-app model.
    actors: ActorPool,
    epoch: Instant,
    /// Monotonic monitor-round counter; rotates the probe order so apps
    /// deferred by one round's deadline are probed first the next round
    /// instead of being structurally starved at the tail.
    round_counter: std::sync::atomic::AtomicUsize,
    /// One scheduler round at a time: the submit hook and the ticker
    /// both call [`scheduler_round`](Self::scheduler_round); a round in
    /// flight makes the other a no-op instead of double-picking victims.
    pub(crate) scheduler_busy: std::sync::atomic::AtomicBool,
}

impl CacsService {
    pub fn new(store: Arc<dyn ObjectStore>, cfg: ServiceConfig) -> Arc<CacsService> {
        Self::new_inner(store, None, cfg)
    }

    /// Construct over a [`TieredStore`]: identical to [`Self::new`] with
    /// the tiers as the object store, plus the scheduler's demote /
    /// promote hooks armed so swapped-out image chains park in the cold
    /// tier as a unit.
    pub fn new_tiered(
        tiers: Arc<crate::storage::tiered::TieredStore>,
        cfg: ServiceConfig,
    ) -> Arc<CacsService> {
        let store: Arc<dyn ObjectStore> = tiers.clone();
        Self::new_inner(store, Some(tiers), cfg)
    }

    fn new_inner(
        store: Arc<dyn ObjectStore>,
        tiers: Option<Arc<crate::storage::tiered::TieredStore>>,
        cfg: ServiceConfig,
    ) -> Arc<CacsService> {
        let workers = if cfg.actor_workers == 0 {
            appthread::default_workers()
        } else {
            cfg.actor_workers
        };
        let ids = IdGen::starting_at(cfg.id_base + 1);
        Arc::new(CacsService {
            cfg,
            store,
            tiers,
            ids,
            shards: (0..N_SHARDS).map(|_| Mutex::new(Inner::empty())).collect(),
            actors: ActorPool::new(workers),
            epoch: Instant::now(),
            round_counter: std::sync::atomic::AtomicUsize::new(0),
            scheduler_busy: std::sync::atomic::AtomicBool::new(false),
        })
    }

    /// Lock the registry shard owning `id`.  A poisoned shard is
    /// recovered, not propagated: a panic inside one critical section
    /// must not brick every later operation on the apps sharing the
    /// shard (the panicking operation's app lands in ERROR via the
    /// normal lifecycle paths).
    fn shard(&self, id: AppId) -> std::sync::MutexGuard<'_, Inner> {
        self.shard_at(id.0 as usize % self.shards.len())
    }

    fn shard_at(&self, idx: usize) -> std::sync::MutexGuard<'_, Inner> {
        self.shards[idx].lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Live actor-pool gauges (worker count, actor count, queued
    /// commands) — saturation is observable before it becomes a timeout.
    pub fn actor_stats(&self) -> PoolStats {
        self.actors.stats()
    }

    /// Subscribe to the unified per-app lifecycle event stream.
    pub fn events(&self) -> std::sync::mpsc::Receiver<AppEvent> {
        self.actors.subscribe()
    }

    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    pub fn store(&self) -> &Arc<dyn ObjectStore> {
        &self.store
    }

    /// POST /coordinators (§5.1).
    pub fn submit(&self, asr: Asr) -> Result<AppId> {
        let factory = build_factory(&asr, &self.cfg)?;
        self.submit_inner(asr, factory)
    }

    /// Test seam: submit with an arbitrary factory (e.g. one that fails
    /// to construct, the §6.3 "dead on arrival" case).
    #[cfg(test)]
    pub(crate) fn submit_with_factory(&self, asr: Asr, factory: AppFactory) -> Result<AppId> {
        self.submit_inner(asr, factory)
    }

    fn submit_inner(&self, asr: Asr, factory: AppFactory) -> Result<AppId> {
        validate_asr(&asr)?;
        let n_vms = asr.n_vms;
        let now = self.now();
        // phase 1: reserve the id + record under the owning shard's
        // lock (PROVISION)
        let id = self.ids.app();
        {
            let mut inner = self.shard(id);
            let mut rec = AppRecord::new(id, asr, now, 0);
            rec.lifecycle.to(now, AppState::Provisioning);
            inner.db.insert(rec);
        }
        // phase 2: provisioning — actor + daemon-tree creation — runs
        // OFF the lock.  v1 held the service lock across the spawn, so
        // one slow provisioning stalled every other REST call.
        #[cfg(test)]
        std::thread::sleep(self.cfg.submit_spawn_delay);
        let handle = Arc::new(self.actors.spawn(
            &id.to_string(),
            factory,
            self.store.clone(),
            self.cfg.step_interval,
            self.cfg.delta.clone(),
        ));
        let monitor = if self.cfg.health_trees {
            let monitor = Arc::new(AppMonitor::start(
                n_vms,
                self.cfg.heartbeat_hop,
                self.cfg.heartbeat_arity,
            ));
            monitor.rewire(&handle);
            Some(monitor)
        } else {
            None
        };
        // phase 3: publish.  A §5.4 DELETE may have raced the spawn —
        // then the record is gone and the fresh actor is retired again.
        let mut inner = self.shard(id);
        let now = self.now();
        let Some(rec) = inner.db.get_mut(id) else {
            drop(inner);
            drop(handle); // retires the just-spawned actor
            anyhow::bail!("coordinator deleted during submit");
        };
        rec.lifecycle.to(now, AppState::Ready);
        rec.lifecycle.to(self.now(), AppState::Running);
        // §5.2 mode 2: arm the periodic-checkpoint clock
        if let Some(period) = rec.asr.ckpt_period {
            rec.periodic_due = Some(now + period);
        }
        inner.handles.insert(id, handle);
        if let Some(monitor) = monitor {
            inner.monitors.insert(id, monitor);
        }
        drop(inner);
        // §2.2 use case 4: an over-capacity submit triggers the
        // scheduler inline — by the time submit returns, either a
        // lower-priority victim is parked or this submit itself was
        // (when the new app is the lowest-priority one)
        if self.cfg.capacity_slots > 0 {
            let moved = self.scheduler_round();
            if !moved.is_empty() {
                log::info!("submit {id}: scheduler rebalanced {moved:?}");
            }
        }
        Ok(id)
    }

    /// Clone the app's actor handle out of the shard lock (bulk calls on
    /// it must not serialize the registry).
    fn handle(&self, id: AppId) -> Option<Arc<AppHandle>> {
        self.shard(id).handles.get(&id).cloned()
    }

    /// GET /coordinators.  Records are snapshotted under each shard lock
    /// and serialized afterwards, so JSON encoding of a 10k-app list
    /// never holds a registry lock.
    pub fn list(&self) -> Vec<Json> {
        let mut recs: Vec<AppRecord> = Vec::new();
        for i in 0..self.shards.len() {
            let inner = self.shard_at(i);
            recs.extend(inner.db.iter().cloned());
        }
        recs.sort_by_key(|r| r.id);
        recs.iter().map(|r| r.to_json()).collect()
    }

    /// GET /coordinators/:id (with live progress attached when the host
    /// thread answers a short control-plane probe; a wedged or busy
    /// host degrades to the cached record instead of hanging the REST
    /// worker for the 120 s data-plane timeout).
    pub fn info(&self, id: AppId) -> Result<Json> {
        let handle = self.handle(id);
        let progress = handle.as_ref().and_then(|h| h.try_progress(CTRL_PROBE_TIMEOUT));
        // snapshot under the shard lock, serialize off it
        let rec = {
            let inner = self.shard(id);
            inner.db.get(id).context("unknown coordinator")?.clone()
        };
        let mut j = rec.to_json();
        // the Young/Daly controller's live interval and its inputs
        if let Some(a) = rec.adaptive.to_json(&self.cfg.adaptive) {
            j.set("adaptive", a);
        }
        if let Some((iter, metric)) = progress {
            j.set("iteration", iter.into());
            if metric.is_finite() {
                j.set("metric", metric.into());
            }
        }
        // actor-plane gauges: per-app mailbox depth plus pool-wide
        // saturation, so backpressure shows up here before it turns
        // into command timeouts
        let stats = self.actors.stats();
        j.set(
            "actor",
            Json::object([
                ("mailbox_depth", handle.map_or(0, |h| h.mailbox_depth()).into()),
                ("pool_workers", stats.workers.into()),
                ("pool_actors", stats.actors.into()),
                ("pool_mailbox_depth", stats.mailbox_depth.into()),
                ("pool_mailbox_max", stats.mailbox_max.into()),
            ]),
        );
        // oversubscription status: slot occupancy, parked-app count and
        // (for a parked app) the cut it will resume from, plus the tier
        // placement gauges when a TieredStore backs the service
        if self.cfg.capacity_slots > 0 || self.tiers.is_some() {
            let (occupied, _, parked) = self.scheduler_snapshot();
            let mut s = Json::object([
                ("capacity_slots", self.cfg.capacity_slots.into()),
                ("occupied", occupied.into()),
                ("swapped", parked.len().into()),
            ]);
            if let Some(seq) = self.parked_seq(id) {
                s.set("parked_seq", seq.into());
            }
            if let Some(t) = &self.tiers {
                s.set("tiers", t.stats().to_json());
            }
            j.set("scheduler", s);
        }
        Ok(j)
    }

    /// POST /coordinators/:id/checkpoints (§5.2 mode 1).  The cut runs
    /// through the dirty-chunk delta engine: after a full first image,
    /// steady-state cuts move only the chunks that changed (see
    /// [`crate::dckpt::delta`]); the returned record says which kind
    /// this cut was.
    pub fn checkpoint(&self, id: AppId) -> Result<CkptRecord> {
        // reserve — but do NOT burn — the next sequence number: the
        // increment commits only on success, so failed attempts leave
        // no gaps in the seq space (delta chains are resolved by
        // explicit base pointers, but contiguous seqs keep chains and
        // retention legible).  The CHECKPOINTING lifecycle gate is what
        // makes the un-incremented reservation race-free.
        let seq = {
            let mut inner = self.shard(id);
            let rec = inner.db.get_mut(id).context("unknown coordinator")?;
            anyhow::ensure!(
                rec.lifecycle.state().can_checkpoint(),
                "cannot checkpoint in state {}",
                rec.lifecycle.state()
            );
            let seq = rec.next_ckpt_seq;
            let now = self.now();
            rec.lifecycle.to(now, AppState::Checkpointing);
            seq
        };
        // drive the image pipeline WITHOUT the service lock (it may move
        // hundreds of MB; list/health/monitor must stay live).  Any
        // failure from here on (including a missing app thread) must
        // land the lifecycle in ERROR — the v1 `?` early-return left it
        // stuck in CHECKPOINTING
        let cut_clock = Instant::now();
        let outcome = match self.handle(id) {
            Some(handle) => handle.checkpoint_auto(seq, self.cfg.with_runtime_overhead),
            None => Err(anyhow::anyhow!("no app thread")),
        };
        // time the app spent stalled in the cut — the C of the
        // Young/Daly controller (the host thread blocks stepping for
        // the whole quiesce + image pipeline)
        let cut_cost = cut_clock.elapsed().as_secs_f64();
        let mut inner = self.shard(id);
        let now = self.now();
        let Some(rec) = inner.db.get_mut(id) else {
            drop(inner);
            // a §5.4 DELETE raced the transfer: the record (and the rest
            // of the stored images) is gone — remove the images this
            // checkpoint just wrote so nothing is orphaned in the store
            let _ = ckptsvc::delete_checkpoint(self.store.as_ref(), &id.to_string(), seq);
            anyhow::bail!("coordinator deleted during checkpoint");
        };
        match outcome {
            Ok(report) => {
                // commit the sequence only now that the cut succeeded
                rec.next_ckpt_seq = rec.next_ckpt_seq.max(seq + 1);
                rec.lifecycle.to(now, AppState::Running);
                let ck = CkptRecord {
                    id: CkptId(seq),
                    seq,
                    taken_at: now,
                    iteration: report.iteration,
                    total_bytes: report.total_bytes(),
                    per_proc_bytes: report.image_bytes.clone(),
                    base_seq: report.base_seq,
                    delta_bytes: report.delta_bytes,
                };
                rec.ckpts.push(ck.clone());
                rec.adaptive.observe_cut(&self.cfg.adaptive, cut_cost);
                Ok(ck)
            }
            Err(e) => {
                rec.lifecycle.to(now, AppState::Error);
                drop(inner);
                // the failed attempt may have left a partial image set
                // at the reserved seq; a later cut will reuse the
                // number, so clean up best-effort — and drop the host
                // thread's digests in case the pipeline actually
                // finished after our reply deadline (a chain must never
                // point at images we just removed)
                let _ = ckptsvc::delete_checkpoint(self.store.as_ref(), &id.to_string(), seq);
                if let Some(h) = self.handle(id) {
                    h.reset_delta();
                }
                Err(e)
            }
        }
    }

    /// GET /coordinators/:id/checkpoints.
    pub fn checkpoints(&self, id: AppId) -> Result<Vec<Json>> {
        // snapshot under the shard lock, serialize off it
        let ckpts = {
            let inner = self.shard(id);
            inner.db.get(id).context("unknown coordinator")?.ckpts.clone()
        };
        Ok(ckpts.iter().map(|c| c.to_json()).collect())
    }

    /// One §5.2 mode-2 ticker round: cut a checkpoint for every RUNNING
    /// app whose `ckpt_period` has elapsed, entirely without user POSTs.
    /// Runs on the Monitoring Manager thread's cadence (and directly
    /// from tests); returns the ids that were checkpointed.
    ///
    /// Each due app is rescheduled *before* the attempt, so a failing
    /// app retries at its period, never in a hot loop; the cut itself
    /// uses the same lifecycle gates and off-lock pipeline as a manual
    /// checkpoint (a busy app — checkpointing, migrating, recovering —
    /// is simply skipped until its next tick).  After a successful cut
    /// the retention policy prunes chains superseded beyond
    /// [`ServiceConfig::ckpt_keep`].
    ///
    /// Due cuts run serially within a round, so one slow cut delays the
    /// others' ticks (their due times are already rescheduled, so
    /// nothing piles up — ticks are skipped, not queued).  That bounds
    /// concurrent image traffic to one periodic cut at a time; delta
    /// cuts keep the common case cheap.  Fan out here if a deployment
    /// ever needs independent periodic cadences under huge full cuts.
    pub fn periodic_round(&self) -> Vec<AppId> {
        let now = self.now();
        let mut due: Vec<AppId> = Vec::new();
        for i in 0..self.shards.len() {
            let mut inner = self.shard_at(i);
            due.extend(
                inner
                    .db
                    .iter_mut()
                    .filter(|rec| {
                        rec.lifecycle.state() == AppState::Running
                            && rec.asr.ckpt_period.is_some()
                            && rec.periodic_due.map(|at| at <= now).unwrap_or(false)
                    })
                    .map(|rec| {
                        // reschedule first: a failed cut must wait a period
                        let period = rec.asr.ckpt_period.expect("filtered on Some");
                        rec.periodic_due = Some(now + period);
                        rec.id
                    }),
            );
        }
        due.sort();
        let mut cut = Vec::new();
        for id in due {
            match self.checkpoint(id) {
                Ok(ck) => {
                    log::info!(
                        "{id}: periodic checkpoint seq {} ({}, {} bytes)",
                        ck.seq,
                        ck.kind(),
                        ck.total_bytes
                    );
                    // Young/Daly: re-derive the tick from the controller
                    // (fed by the cut the service just timed), replacing
                    // the fixed-period reschedule made before the cut.
                    // Failed cuts keep that fixed-period retry.
                    if self.cfg.adaptive.enabled {
                        let now = self.now();
                        let mut inner = self.shard(id);
                        if let Some(rec) = inner.db.get_mut(id) {
                            if let Some(fixed) = rec.asr.ckpt_period {
                                let next =
                                    rec.adaptive.next_period(&self.cfg.adaptive, fixed);
                                rec.periodic_due = Some(now + next);
                            }
                        }
                    }
                    self.prune_checkpoints(id);
                    cut.push(id);
                }
                // a lifecycle refusal (busy app) or pipeline failure:
                // the next tick retries; pipeline failures also park
                // the app in ERROR for the monitor, same as manual cuts
                Err(e) => log::warn!("{id}: periodic checkpoint skipped: {e}"),
            }
        }
        cut
    }

    /// Retention for periodic cuts: keep every cut belonging to the
    /// chains rooted at the newest [`ServiceConfig::ckpt_keep`] full
    /// images (plus any base a kept delta still points at), delete the
    /// rest — store first, then record, reusing the torn-set-safe
    /// ordering of [`Self::delete_checkpoint`].
    fn prune_checkpoints(&self, id: AppId) {
        let keep_chains = self.cfg.ckpt_keep;
        if keep_chains == 0 {
            return;
        }
        let doomed: Vec<u64> = {
            let inner = self.shard(id);
            let Some(rec) = inner.db.get(id) else { return };
            let mut keep: BTreeSet<u64> = BTreeSet::new();
            let mut fulls = 0usize;
            for ck in rec.ckpts.iter().rev() {
                keep.insert(ck.seq);
                if ck.base_seq.is_none() {
                    fulls += 1;
                    if fulls >= keep_chains {
                        break;
                    }
                }
            }
            if fulls < keep_chains {
                return; // not enough chains yet to supersede anything
            }
            // transitive base closure: a kept delta must keep its base
            // even when the base sits outside the newest-K window
            loop {
                let missing: Vec<u64> = rec
                    .ckpts
                    .iter()
                    .filter(|ck| keep.contains(&ck.seq))
                    .filter_map(|ck| ck.base_seq)
                    .filter(|base| !keep.contains(base))
                    .collect();
                if missing.is_empty() {
                    break;
                }
                keep.extend(missing);
            }
            rec.ckpts
                .iter()
                .map(|ck| ck.seq)
                .filter(|seq| !keep.contains(seq))
                .collect()
        };
        // newest-first: a doomed delta must go before the doomed base
        // it chains to, or the base-of-a-chain guard in
        // [`Self::delete_checkpoint`] would refuse the base
        for seq in doomed.into_iter().rev() {
            if let Err(e) = self.delete_checkpoint(id, seq) {
                // a failed store delete keeps the record; the next
                // periodic cut retries the prune
                log::warn!("{id}: pruning checkpoint seq {seq} failed: {e}");
            }
        }
    }

    /// POST /coordinators/:id/checkpoints/:seq — restart (§5.3).
    pub fn restart(&self, id: AppId, seq: Option<u64>) -> Result<u64> {
        {
            let mut inner = self.shard(id);
            let rec = inner.db.get_mut(id).context("unknown coordinator")?;
            let now = self.now();
            anyhow::ensure!(
                rec.lifecycle.state().can_restart()
                    || rec.lifecycle.state() == AppState::Restarting,
                "cannot restart in state {}",
                rec.lifecycle.state()
            );
            if rec.lifecycle.state() != AppState::Restarting {
                rec.lifecycle.to(now, AppState::Restarting);
            }
        }
        // restore runs without the service lock; a missing app thread is
        // a restore failure, not a `?` early return — the lifecycle must
        // land in ERROR, not stay RESTARTING
        let result = match self.handle(id) {
            Some(handle) => handle.restore(seq),
            None => Err(anyhow::anyhow!("no app thread")),
        };
        let mut inner = self.shard(id);
        let now = self.now();
        let rec = inner.db.get_mut(id).context("unknown coordinator")?;
        match result {
            Ok(used) => {
                rec.lifecycle.to(now, AppState::Running);
                Ok(used)
            }
            Err(e) => {
                rec.lifecycle.to(now, AppState::Error);
                Err(e)
            }
        }
    }

    /// DELETE /coordinators/:id/checkpoints/:seq.
    ///
    /// The store delete runs *first*: v1 dropped the [`CkptRecord`]
    /// before touching the store, so a store error left orphaned images
    /// that no longer appeared in `GET /checkpoints` (invisible to both
    /// the user and the §5.4 cleanup).  Now a failed store delete
    /// keeps the record — the checkpoint stays visible and the DELETE
    /// can simply be retried — *unless* the failure was partial and
    /// tore the image set: a checkpoint missing images must not stay
    /// listed as restorable (recovery would restore from a corrupt
    /// set), so a torn record is dropped and the error still surfaced;
    /// the leftover images remain deletable by retry or app DELETE.
    pub fn delete_checkpoint(&self, id: AppId, seq: u64) -> Result<usize> {
        let was_latest = {
            let inner = self.shard(id);
            let rec = inner.db.get(id).context("unknown coordinator")?;
            // a cut in flight may be a delta chaining to exactly this
            // seq: its record lands only after the pipeline finishes, so
            // the dependent-guard below cannot see it yet.  Deleting the
            // base under it would strand that cut the moment it commits
            // (the §5.2 ticker racing a manual DELETE is the concrete
            // interleaving) — refuse, the DELETE is retryable.
            let state = rec.lifecycle.state();
            anyhow::ensure!(
                state != AppState::Checkpointing && state != AppState::Migrating,
                "cannot delete checkpoint {seq} while a cut is in flight (state {state})"
            );
            // a cut that later deltas chain to must not go away under
            // them: the dependents would stay listed as restorable but
            // resolve to a missing base (and the host tracker would
            // keep extending the broken chain).  Delete the dependents
            // first (newest-first), or the whole app.
            if let Some(dep) = rec.ckpts.iter().find(|c| c.base_seq == Some(seq)) {
                anyhow::bail!(
                    "checkpoint {seq} is the base of delta checkpoint {}; delete the dependent cuts first",
                    dep.seq
                );
            }
            rec.ckpts.iter().map(|c| c.seq).max() == Some(seq)
        };
        // deleting the newest cut invalidates the host thread's delta
        // digests (they describe exactly that cut).  Reset them BEFORE
        // the store delete: the host command queue is FIFO, so a
        // checkpoint command enqueued after this point re-roots a full
        // image instead of emitting a delta whose base is mid-deletion —
        // the other half of the ticker/DELETE race, where the cut starts
        // just after the guard above saw a quiet lifecycle.
        if was_latest {
            if let Some(h) = self.handle(id) {
                h.reset_delta();
            }
        }
        let result = ckptsvc::delete_checkpoint(self.store.as_ref(), &id.to_string(), seq);
        let intact = if result.is_ok() {
            false // all images gone; the record must go too
        } else {
            // how much of the image set survived the failed delete?
            let prefix = format!("{id}/ckpt-{seq}/");
            match self.store.list(&prefix) {
                // can't tell what survived (the store is refusing even
                // reads): keep the record, so the DELETE stays
                // retryable — dropping it on a transient outage would
                // silently orphan a possibly fully intact image set
                Err(_) => true,
                Ok(keys) => {
                    let inner = self.shard(id);
                    inner
                        .db
                        .get(id)
                        .and_then(|rec| rec.ckpts.iter().find(|c| c.seq == seq))
                        .map(|ck| keys.len() >= ck.per_proc_bytes.len())
                        .unwrap_or(false)
                }
            }
        };
        if !intact {
            // drop the record (the digest reset already happened before
            // the store delete, while the guard knew seq was the latest)
            let mut inner = self.shard(id);
            if let Some(rec) = inner.db.get_mut(id) {
                rec.ckpts.retain(|c| c.seq != seq);
            }
        }
        result
    }

    /// DELETE /coordinators/:id (§5.4: remove DB entry, stored images,
    /// release resources).
    ///
    /// The record leaves the database *before* the store purge: an
    /// [`upload_image`](Self::upload_image) racing this call re-checks
    /// the record after its store write and, finding it gone, removes
    /// its own key — whichever side runs last cleans up, so no orphan
    /// can survive the race in either order.
    pub fn delete(&self, id: AppId) -> Result<()> {
        let (handle, monitor) = {
            let mut inner = self.shard(id);
            let rec = inner.db.get_mut(id).context("unknown coordinator")?;
            let now = self.now();
            rec.lifecycle.to(now, AppState::Terminating);
            rec.lifecycle.to(now, AppState::Terminated);
            inner.db.remove(id);
            inner.swapped.remove(&id); // a parked app's bookkeeping goes too
            (inner.handles.remove(&id), inner.monitors.remove(&id))
        };
        drop(handle); // joins the app thread when last ref (releases the "VMs")
        drop(monitor); // shuts the app's monitoring tree down
        // with a TieredStore underneath, list/delete route through the
        // tier metadata — a swapped app's cold-parked chain is purged
        // by the same call that empties a running app's hot images
        let _ = ckptsvc::delete_all(self.store.as_ref(), &id.to_string());
        Ok(())
    }

    /// Upload one checkpoint image (migration receive path, §5.3:
    /// "n POST requests are sent to the corresponding checkpoints
    /// resource to upload a set of checkpoint images").
    pub fn upload_image(&self, id: AppId, seq: u64, proc: usize, data: &[u8]) -> Result<()> {
        self.upload_image_stream(id, seq, proc, None, &mut &data[..]).map(|_| ())
    }

    /// Streaming variant of [`upload_image`](Self::upload_image): the
    /// body flows straight into the store's
    /// [`crate::storage::PutWriter`] — the REST layer feeds it the
    /// (chunk-decoded) request body, so an image is never materialized
    /// as one buffer on the receive side.  Returns the byte count.
    ///
    /// `base_seq` is the sender's chain metadata (the `x-base-seq`
    /// upload header, cut-level).  The first wire bytes are sniffed for
    /// the v2 delta version, so only images that really are deltas
    /// count toward the record's `delta_bytes` (a mixed cut's
    /// full-fallback proc images don't) and a delta cut registers as
    /// one — the receiving CACS's `GET /checkpoints` stays honest
    /// about what it holds.
    pub fn upload_image_stream(
        &self,
        id: AppId,
        seq: u64,
        proc: usize,
        base_seq: Option<u64>,
        body: &mut dyn std::io::Read,
    ) -> Result<u64> {
        {
            let inner = self.shard(id);
            anyhow::ensure!(inner.db.get(id).is_some(), "unknown coordinator");
        }
        let key = ckptsvc::image_key(&id.to_string(), seq, proc);
        // the transfer runs without the service lock.  Peek the
        // magic+version prefix as it flows by: it tells full from
        // delta without buffering the image.
        let mut head = [0u8; 6];
        let mut got = 0usize;
        while got < head.len() {
            match body.read(&mut head[got..]) {
                Ok(0) => break,
                Ok(k) => got += k,
                Err(e) => return Err(e).with_context(|| format!("store put {key}")),
            }
        }
        let is_delta_img = got == head.len()
            && &head[..4] == crate::dckpt::image::MAGIC
            && u16::from_le_bytes([head[4], head[5]]) == crate::dckpt::image::VERSION_DELTA;
        let n = {
            let mut w = self
                .store
                .put_writer(&key)
                .map_err(|e| anyhow::anyhow!("store put {key}: {e}"))?;
            w.write_all(&head[..got])
                .with_context(|| format!("store put {key}"))?;
            std::io::copy(body, &mut w).with_context(|| format!("store put {key}"))?;
            w.finish().map_err(|e| anyhow::anyhow!("store put {key}: {e}"))?
        };
        // register/refresh the checkpoint record — re-checking the
        // record: a §5.4 DELETE may have raced the transfer (v1 called
        // `.unwrap()` here and panicked the REST worker).  The record
        // is removed before the DELETE's store purge, so when it is
        // gone we remove the just-written orphan ourselves.
        let delta_img_bytes = if is_delta_img { n } else { 0 };
        let img_base_seq = if is_delta_img { base_seq } else { None };
        let mut inner = self.shard(id);
        let now = self.now();
        let Some(rec) = inner.db.get_mut(id) else {
            drop(inner);
            let _ = self.store.delete(&key);
            anyhow::bail!("coordinator deleted during upload");
        };
        if let Some(ck) = rec.ckpts.iter_mut().find(|c| c.seq == seq) {
            while ck.per_proc_bytes.len() <= proc {
                ck.per_proc_bytes.push(0);
            }
            // count delta bytes on a proc's first upload only: a
            // replacement upload can't double-count (we don't know the
            // replaced image's kind, so its accounting stands)
            if ck.per_proc_bytes[proc] == 0 {
                ck.delta_bytes += delta_img_bytes;
            }
            ck.per_proc_bytes[proc] = n;
            ck.total_bytes = ck.per_proc_bytes.iter().sum();
            if img_base_seq.is_some() {
                ck.base_seq = img_base_seq;
            }
        } else {
            let mut per_proc = vec![0u64; proc + 1];
            per_proc[proc] = n;
            rec.ckpts.push(CkptRecord {
                id: CkptId(seq),
                seq,
                taken_at: now,
                iteration: 0,
                total_bytes: n,
                per_proc_bytes: per_proc,
                base_seq: img_base_seq,
                delta_bytes: delta_img_bytes,
            });
            rec.ckpts.sort_by_key(|c| c.seq);
            rec.next_ckpt_seq = rec.next_ckpt_seq.max(seq + 1);
        }
        Ok(n)
    }

    /// Download one checkpoint image (migration send path).
    pub fn download_image(&self, id: AppId, seq: u64, proc: usize) -> Result<Vec<u8>> {
        let key = ckptsvc::image_key(&id.to_string(), seq, proc);
        self.store
            .get(&key)
            .map_err(|e| anyhow::anyhow!("store get: {e}"))
    }

    // --- §5.3 cross-CACS migration plumbing (driven by
    // [`super::migrate::migrate`], which owns the orchestration) -------

    /// Atomically claim the app for migration: validate the lifecycle
    /// (only RUNNING may migrate — anything else is a 409 at the REST
    /// layer), move it to MIGRATING and reserve the checkpoint
    /// sequence.  The caller quiesces and checkpoints via the returned
    /// handle *without* the service lock.
    pub(crate) fn begin_migration(
        &self,
        id: AppId,
    ) -> Result<MigrationTicket, MigrateStartError> {
        let now = self.now();
        let mut inner = self.shard(id);
        let inner = &mut *inner;
        let Some(rec) = inner.db.get_mut(id) else {
            return Err(MigrateStartError::UnknownCoordinator);
        };
        let state = rec.lifecycle.state();
        if !state.can_migrate() {
            return Err(MigrateStartError::BadState(state));
        }
        let Some(handle) = inner.handles.get(&id).cloned() else {
            return Err(MigrateStartError::NoAppThread);
        };
        rec.lifecycle.to(now, AppState::Migrating);
        let seq = rec.next_ckpt_seq;
        rec.next_ckpt_seq += 1;
        Ok(MigrationTicket {
            handle,
            seq,
            asr: rec.asr.clone(),
            with_overhead: self.cfg.with_runtime_overhead,
        })
    }

    /// Reserve a further checkpoint sequence while the app is claimed
    /// MIGRATING (the pre-copy orchestration cuts twice: once while the
    /// app still runs, once at the quiesced barrier).  The MIGRATING
    /// gate keeps user checkpoints out, so the increment cannot race.
    pub(crate) fn reserve_migration_seq(&self, id: AppId) -> Result<u64> {
        let mut inner = self.shard(id);
        let rec = inner
            .db
            .get_mut(id)
            .context("coordinator deleted during migration")?;
        anyhow::ensure!(
            rec.lifecycle.state() == AppState::Migrating,
            "cannot reserve a migration checkpoint in state {}",
            rec.lifecycle.state()
        );
        let seq = rec.next_ckpt_seq;
        rec.next_ckpt_seq += 1;
        Ok(seq)
    }

    /// Register the checkpoint the migration took (the MIGRATING state
    /// means no user checkpoint can race this sequence number).
    pub(crate) fn record_migration_ckpt(
        &self,
        id: AppId,
        report: &ckptsvc::CheckpointReport,
    ) -> Result<CkptRecord> {
        let now = self.now();
        let mut inner = self.shard(id);
        let rec = inner
            .db
            .get_mut(id)
            .context("coordinator deleted during migration")?;
        let ck = CkptRecord {
            id: CkptId(report.seq),
            seq: report.seq,
            taken_at: now,
            iteration: report.iteration,
            total_bytes: report.total_bytes(),
            per_proc_bytes: report.image_bytes.clone(),
            base_seq: report.base_seq,
            delta_bytes: report.delta_bytes,
        };
        rec.ckpts.push(ck.clone());
        Ok(ck)
    }

    /// The per-cut chain needed to restore checkpoint `seq`: walk the
    /// recorded `base_seq` links back to the rooting full cut; returned
    /// oldest-first (the transfer order).  Per-proc chains are subsets
    /// of this cut-level chain (a proc that fell back to a full image
    /// mid-chain simply stops walking earlier).
    pub(crate) fn ckpt_chain(&self, id: AppId, seq: u64) -> Result<Vec<CkptRecord>> {
        let inner = self.shard(id);
        let rec = inner.db.get(id).context("unknown coordinator")?;
        let mut chain = Vec::new();
        let mut cur = Some(seq);
        while let Some(s) = cur {
            anyhow::ensure!(
                chain.len() <= 64,
                "checkpoint chain for seq {seq} exceeds 64 links (cycle?)"
            );
            let ck = rec
                .ckpts
                .iter()
                .find(|c| c.seq == s)
                .with_context(|| format!("chain for seq {seq}: missing base ckpt-{s}"))?;
            chain.push(ck.clone());
            cur = ck.base_seq;
        }
        chain.reverse();
        Ok(chain)
    }

    /// A migration failed before the source was touched: roll the
    /// lifecycle back to RUNNING and resume stepping.  (A concurrent
    /// DELETE may have removed the record; then there is nothing to
    /// roll back.)
    pub(crate) fn abort_migration(&self, id: AppId) {
        let handle = {
            let now = self.now();
            let mut inner = self.shard(id);
            let inner = &mut *inner;
            if let Some(rec) = inner.db.get_mut(id) {
                if rec.lifecycle.state() == AppState::Migrating {
                    rec.lifecycle.to(now, AppState::Running);
                }
            }
            inner.handles.get(&id).cloned()
        };
        if let Some(h) = handle {
            h.resume();
        }
    }

    /// The clone is confirmed RUNNING on the destination: terminate the
    /// source (§5.3 "migration = clone + terminate source").  The host
    /// thread is joined, the stored images purged, and a TERMINATED
    /// tombstone with `migrated_to` kept in the database so the move
    /// stays auditable (a user DELETE removes the tombstone too).
    pub(crate) fn complete_migration(&self, id: AppId, migrated_to: String) -> Result<()> {
        let (handle, monitor) = {
            let now = self.now();
            let mut inner = self.shard(id);
            let inner = &mut *inner;
            let rec = inner
                .db
                .get_mut(id)
                .context("coordinator deleted during migration")?;
            rec.migrated_to = Some(migrated_to);
            rec.lifecycle.to(now, AppState::Terminating);
            (inner.handles.remove(&id), inner.monitors.remove(&id))
        };
        drop(handle); // joins the host thread — releases the "VMs"
        drop(monitor); // the tombstone needs no monitoring tree
        let _ = ckptsvc::delete_all(self.store.as_ref(), &id.to_string());
        let now = self.now();
        let mut inner = self.shard(id);
        if let Some(rec) = inner.db.get_mut(id) {
            rec.lifecycle.to(now, AppState::Terminated);
        }
        Ok(())
    }

    /// Test seam: drive a (legal) lifecycle transition directly, e.g.
    /// to hold an app in CHECKPOINTING while probing REST guards.
    #[cfg(test)]
    pub(crate) fn force_state(&self, id: AppId, next: AppState) -> bool {
        let now = self.now();
        let mut inner = self.shard(id);
        inner
            .db
            .get_mut(id)
            .map(|r| r.lifecycle.to(now, next))
            .unwrap_or(false)
    }

    /// Raw per-proc health snapshot (legacy bool view; examples and
    /// tests poll this).  Bounded by the control-plane probe timeout,
    /// and padded to `n_vms`: a construct-failed app answers with no
    /// flags at all, which must read as "all down" — v1 let the empty
    /// reply pass through and `.iter().all(...)`-style callers saw a
    /// dead app as perfectly healthy.
    pub fn health(&self, id: AppId) -> Result<Vec<bool>> {
        let (n, handle) = {
            let inner = self.shard(id);
            let rec = inner.db.get(id).context("unknown coordinator")?;
            (rec.asr.n_vms, inner.handles.get(&id).cloned())
        };
        let Some(handle) = handle else {
            return Ok(vec![false; n]); // host gone: nothing is healthy
        };
        match handle.try_health(CTRL_PROBE_TIMEOUT) {
            Some(mut flags) => {
                let len = flags.len().max(n);
                flags.resize(len, false);
                Ok(flags)
            }
            None => anyhow::bail!("app thread did not answer the health probe"),
        }
    }

    /// Fault injection (examples/tests): wedge the app's host thread —
    /// it stops servicing commands entirely, the "guest froze" failure
    /// the §6.3 monitor must detect within the heartbeat budget.
    pub fn wedge_vm(&self, id: AppId) -> Result<()> {
        let inner = self.shard(id);
        let handle = inner.handles.get(&id).context("unknown coordinator")?;
        handle.wedge();
        Ok(())
    }

    /// Fault injection (examples/tests): kill process `proc`.
    pub fn kill_proc(&self, id: AppId, proc: usize) -> Result<()> {
        let inner = self.shard(id);
        let handle = inner.handles.get(&id).context("unknown coordinator")?;
        handle.kill_proc(proc);
        Ok(())
    }

    /// Pause/resume (oversubscription example).
    pub fn pause(&self, id: AppId) -> Result<()> {
        let inner = self.shard(id);
        inner.handles.get(&id).context("unknown coordinator")?.pause();
        Ok(())
    }

    pub fn resume(&self, id: AppId) -> Result<()> {
        let inner = self.shard(id);
        inner.handles.get(&id).context("unknown coordinator")?.resume();
        Ok(())
    }

    // --- §2.2 use case 4: oversubscription swap-out / swap-in --------

    /// Swap a RUNNING app out: checkpoint it, release its actor slot
    /// and park the image chain (demoted to the cold tier when the
    /// service runs over a [`crate::storage::tiered::TieredStore`]).
    /// The app lands in SWAPPED_OUT with progress frozen at the cut;
    /// [`Self::swap_in`] — or the scheduler, once capacity frees up —
    /// resumes it at exactly that iteration.  Returns the parked seq.
    pub fn swap_out(&self, id: AppId) -> Result<u64> {
        {
            let inner = self.shard(id);
            let rec = inner.db.get(id).context("unknown coordinator")?;
            let state = rec.lifecycle.state();
            anyhow::ensure!(state.can_swap_out(), "cannot swap out in state {state}");
        }
        // the cut reuses the full checkpoint pipeline (seq reservation,
        // delta chains, Young/Daly accounting) — and its CHECKPOINTING
        // gate, so no user checkpoint can race the swap cut
        let ck = self.checkpoint(id)?;
        // park: transition + unpublish the handle under the shard lock
        let handle = {
            let now = self.now();
            let mut inner = self.shard(id);
            let inner = &mut *inner;
            let rec = inner
                .db
                .get_mut(id)
                .context("coordinator deleted during swap-out")?;
            let state = rec.lifecycle.state();
            // a user operation may have claimed the app between the cut
            // committing and this lock: the cut stays as an ordinary
            // checkpoint and the swap is refused
            anyhow::ensure!(state.can_swap_out(), "swap-out raced: app moved to {state}");
            rec.lifecycle.to(now, AppState::SwappedOut);
            inner.swapped.insert(id, ck.seq);
            inner.handles.remove(&id)
        };
        // release the slot OFF the lock: stop the actor and wait
        // (bounded) for the worker slot to free — pause would keep the
        // worker pinned, which is exactly what oversubscription must
        // not do
        if let Some(h) = handle {
            if !h.release_slot() {
                log::warn!("{id}: swapped-out actor did not release its slot within grace");
            }
            drop(h);
        }
        // demote the whole delta chain newest-link-first, so the parked
        // base is never colder than a delta that chains to it
        if let Some(tiers) = &self.tiers {
            match self.ckpt_chain(id, ck.seq) {
                Ok(chain) => {
                    for c in chain.iter().rev() {
                        let prefix = format!("{id}/ckpt-{}/", c.seq);
                        if let Err(e) =
                            tiers.demote(&prefix, crate::storage::tiered::Tier::Cold)
                        {
                            // the park is still valid: reads route via
                            // the tier metadata wherever the images sit
                            log::warn!("{id}: demoting {prefix} failed: {e}");
                        }
                    }
                }
                Err(e) => log::warn!("{id}: swap-out chain walk failed: {e}"),
            }
        }
        self.actors
            .emit(&id.to_string(), appthread::AppEventKind::SwappedOut { seq: ck.seq });
        Ok(ck.seq)
    }

    /// Swap a parked app back in: re-provision a host from the stored
    /// ASR, promote the parked image chain out of the cold tier
    /// (oldest-link-first: the rooting full image must be hot before
    /// the deltas that resolve against it) and restore at exactly the
    /// parked cut.  Returns the seq the app resumed from.
    pub fn swap_in(&self, id: AppId) -> Result<u64> {
        let (asr, seq) = {
            let now = self.now();
            let mut inner = self.shard(id);
            let inner = &mut *inner;
            let rec = inner.db.get_mut(id).context("unknown coordinator")?;
            let state = rec.lifecycle.state();
            anyhow::ensure!(state.can_swap_in(), "cannot swap in from state {state}");
            let seq = inner
                .swapped
                .remove(&id)
                .context("swapped app has no parked cut")?;
            rec.lifecycle.to(now, AppState::Restarting);
            (rec.asr.clone(), seq)
        };
        // promote oldest-first; a failed promote is non-fatal — the
        // TieredStore read path serves (and read-through promotes)
        // images from whatever tier they are in
        if let Some(tiers) = &self.tiers {
            match self.ckpt_chain(id, seq) {
                Ok(chain) => {
                    for c in &chain {
                        let prefix = format!("{id}/ckpt-{}/", c.seq);
                        if let Err(e) =
                            tiers.promote(&prefix, crate::storage::tiered::Tier::Hot)
                        {
                            log::warn!("{id}: promoting {prefix} failed: {e}");
                        }
                    }
                }
                Err(e) => log::warn!("{id}: swap-in chain walk failed: {e}"),
            }
        }
        // re-provision + publish, the §6.3 case-1 pattern: spawn
        // off-lock, re-check the record against a racing DELETE before
        // publishing the fresh handle
        let factory = match build_factory(&asr, &self.cfg) {
            Ok(f) => f,
            Err(e) => {
                self.set_error(id);
                return Err(e);
            }
        };
        let handle = Arc::new(self.actors.spawn(
            &id.to_string(),
            factory,
            self.store.clone(),
            self.cfg.step_interval,
            self.cfg.delta.clone(),
        ));
        let monitor = {
            let mut inner = self.shard(id);
            if inner.db.get(id).is_none() {
                drop(inner);
                drop(handle);
                anyhow::bail!("coordinator deleted during swap-in");
            }
            inner.handles.insert(id, handle.clone());
            inner.monitors.get(&id).cloned()
        };
        if let Some(m) = monitor {
            m.rewire(&handle);
        }
        let used = self.restart(id, Some(seq))?;
        self.actors
            .emit(&id.to_string(), appthread::AppEventKind::SwappedIn { seq: used });
        Ok(used)
    }

    /// The seq a SWAPPED_OUT app was parked at, if any.
    pub fn parked_seq(&self, id: AppId) -> Option<u64> {
        self.shard(id).swapped.get(&id).copied()
    }

    /// The configured slot capacity (0 = unlimited, scheduler off).
    pub(crate) fn capacity_slots(&self) -> usize {
        self.cfg.capacity_slots
    }

    /// Scheduler snapshot: (occupied slots, RUNNING candidates, parked
    /// candidates).  Occupancy is the number of live actor handles —
    /// the ground truth for "holds a slot": paused apps keep theirs,
    /// swapped apps gave theirs up.
    pub(crate) fn scheduler_snapshot(
        &self,
    ) -> (usize, Vec<scheduler::Candidate>, Vec<scheduler::Candidate>) {
        let mut occupied = 0usize;
        let mut running = Vec::new();
        let mut parked = Vec::new();
        for i in 0..self.shards.len() {
            let inner = self.shard_at(i);
            for rec in inner.db.iter() {
                let has_handle = inner.handles.contains_key(&rec.id);
                if has_handle {
                    occupied += 1;
                }
                let c = scheduler::Candidate { id: rec.id, priority: rec.asr.priority };
                match rec.lifecycle.state() {
                    AppState::Running if has_handle => running.push(c),
                    AppState::SwappedOut => parked.push(c),
                    _ => {}
                }
            }
        }
        (occupied, running, parked)
    }

    /// App ids currently registered (all shards, ascending).
    pub fn app_ids(&self) -> Vec<AppId> {
        let mut ids = Vec::new();
        for i in 0..self.shards.len() {
            ids.extend(self.shard_at(i).db.ids_sorted());
        }
        ids.sort();
        ids
    }

    pub fn state(&self, id: AppId) -> Option<AppState> {
        self.shard(id).db.get(id).map(|r| r.lifecycle.state())
    }

    /// One §6.3 health report for an app, produced by a heartbeat over
    /// its per-app [`AppMonitor`] broadcast tree.  The leaf hooks read
    /// per-proc health through a bounded non-blocking probe of the host
    /// thread, so a wedged host (or a construct-failed app answering
    /// with no flags) is reported *unreachable within the heartbeat
    /// budget* — v1 synthesized this from one blocking
    /// `AppHandle::health()` with the 120 s data-plane timeout.
    pub fn health_report(&self, id: AppId) -> Result<HealthReport> {
        Ok(self.health_status(id)?.report)
    }

    /// [`Self::health_report`] plus the probe's detection-latency
    /// accounting — the payload of `GET /coordinators/:id/health`.
    ///
    /// The heartbeat is live only for RUNNING / ERROR apps.  While the
    /// data plane legitimately owns the host thread (a checkpoint,
    /// restore or migration in flight blocks the command queue for as
    /// long as the images take), a probe would misread "busy" as a
    /// total outage — those states serve the last completed verdict
    /// with `live: false` instead.
    pub fn health_status(&self, id: AppId) -> Result<HealthStatus> {
        let (n, state, monitor) = {
            let inner = self.shard(id);
            let rec = inner.db.get(id).context("unknown coordinator")?;
            (rec.asr.n_vms, rec.lifecycle.state(), inner.monitors.get(&id).cloned())
        };
        let live = matches!(state, AppState::Running | AppState::Error);
        // the heartbeat runs without the service lock.  A non-live app
        // with no completed probe yet gets the all-unreachable verdict
        // (`waves: 0`, `live: false` flag it as "no evidence"): absence
        // of a verdict must never read as healthy — that is the exact
        // hole the construct-failed fix closes elsewhere.
        let probe = match monitor {
            Some(m) if live => m.probe(),
            Some(m) => m.last_probe().unwrap_or_else(|| HealthProbe::unreachable(n)),
            None => HealthProbe::unreachable(n),
        };
        Ok(HealthStatus {
            report: probe.report,
            n_vms: n,
            state,
            live,
            rtt: probe.rtt,
            waves: probe.waves,
            budget: probe.budget,
            hop: self.cfg.heartbeat_hop,
            arity: self.cfg.heartbeat_arity.max(2),
        })
    }

    /// One monitoring round over all apps (§6.3); returns the ids that
    /// entered recovery.  Called by the monitor thread and directly by
    /// tests.
    ///
    /// Every app's heartbeat fans out **concurrently** (on the
    /// dedicated [`heartbeat_pool`]) under one whole-round deadline, so
    /// a single wedged host thread costs its own tree budget — not a
    /// serialized 120 s slot in front of every other app, the v1
    /// failure mode that made detection latency O(n_apps × timeout).
    /// Apps the deadline cuts off are deferred (and logged), never
    /// silently reported healthy.
    ///
    /// Two recovery cases per the paper: an *unreachable* virtual
    /// cluster is re-provisioned and restored from the last image
    /// ([`Self::reprovision_and_restore`]); *unhealthy* processes on a
    /// reachable cluster restart in place ([`Self::restart`]).  Apps
    /// already in ERROR that have a usable checkpoint take the §5.3
    /// passive-recovery path (ERROR → RESTARTING).  Recovery is claimed
    /// per app, so concurrent rounds never double-recover one app.
    pub fn monitor_round(&self) -> Vec<AppId> {
        let mut recovered = vec![];
        if !self.cfg.health_trees {
            // no broadcast trees exist: every probe would read
            // "unreachable" and spiral the whole fleet into recovery
            return recovered;
        }
        type Target = (AppId, AppState, bool, usize, Option<Arc<AppMonitor>>);
        let mut targets: Vec<Target> = Vec::new();
        for i in 0..self.shards.len() {
            let inner = self.shard_at(i);
            targets.extend(
                inner
                    .db
                    .iter()
                    .filter(|r| {
                        matches!(r.lifecycle.state(), AppState::Running | AppState::Error)
                    })
                    .map(|r| {
                        (
                            r.id,
                            r.lifecycle.state(),
                            r.latest_ckpt().is_some(),
                            r.asr.n_vms,
                            inner.monitors.get(&r.id).cloned(),
                        )
                    }),
            );
        }
        targets.sort_by_key(|t| t.0);
        if targets.is_empty() {
            return recovered;
        }
        // rotate the probe order each round: the deadline below defers
        // whatever did not get probed in time, and with a fixed (db)
        // order the same tail apps would be deferred every round during
        // a fleet-wide outage — rotation guarantees every app is at the
        // head of the order once per `targets.len()` rounds
        let rot = self
            .round_counter
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed)
            % targets.len();
        targets.rotate_left(rot);
        // whole-round deadline for the PROBE phase: twice the widest
        // tree's heartbeat budget (probe + resolve-wave slack), floored
        // by the monitor period — detection is bounded regardless of
        // how many apps are wedged.  Recovery actions for apps that
        // failed the probe then run serially below (each one gated by a
        // patient confirm), so the round's total time scales with the
        // number of *confirmed-failed* apps, never with fleet size.
        let per_app = targets
            .iter()
            .filter_map(|t| t.4.as_ref().map(|m| m.budget()))
            .max()
            .unwrap_or(Duration::from_millis(500));
        let round_deadline = Instant::now()
            + (per_app * 2).max(self.cfg.monitor_period.unwrap_or(Duration::ZERO));
        let probes = heartbeat_pool().map(targets, move |(id, state, has_ckpt, n_vms, mon)| {
            if Instant::now() >= round_deadline {
                return (id, state, has_ckpt, n_vms, None); // deferred, see below
            }
            let probe = match &mon {
                Some(m) => m.probe(),
                None => HealthProbe::unreachable(n_vms),
            };
            (id, state, has_ckpt, n_vms, Some(probe))
        });
        let mut deferred = 0usize;
        for (id, state, has_ckpt, n_vms, probe) in probes {
            let Some(probe) = probe else {
                deferred += 1;
                continue;
            };
            let report = probe.report;
            if state == AppState::Running && report.all_healthy() {
                continue;
            }
            if state == AppState::Error && !self.cfg.auto_recover {
                continue; // a user DELETE or manual restart must resolve it
            }
            if !report.all_healthy() {
                log::warn!(
                    "{id}: unhealthy {:?} unreachable {:?} (detected in {:?} of {:?} budget, {} wave(s))",
                    report.unhealthy,
                    report.unreachable,
                    probe.rtt,
                    probe.budget,
                    probe.waves
                );
            }
            // claim the app; a concurrent round holding it (or having
            // just recovered it) must not be doubled up on
            if !self.claim_recovery(id) {
                continue;
            }
            // re-check the lifecycle under the claim: a user operation
            // (or a DELETE) may own the app since the probe
            let state_now = self.state(id);
            if !matches!(state_now, Some(AppState::Running) | Some(AppState::Error)) {
                self.release_recovery(id);
                continue;
            }
            if !self.cfg.auto_recover || !has_ckpt {
                self.set_error(id);
                self.release_recovery(id);
                continue;
            }
            // Patient second opinion directly on the host thread before
            // anything destructive: the tree's verdict is tuned for fast
            // detection (hop-bounded), so an app that is merely slow or
            // briefly busy — or that a concurrent round already
            // recovered — must not be torn down on stale evidence.  The
            // confirm also picks the recovery case on FRESH data: a host
            // that wedged after the probe must go down the re-provision
            // path, not block a 120 s in-place restore.
            let confirm = self
                .handle(id)
                .and_then(|h| h.try_health(RECOVERY_CONFIRM_TIMEOUT));
            let result = match confirm {
                // §6.3 case 1: no host, or it cannot answer even a
                // patient probe — new "VMs" + restore.  Flags shorter
                // than n_vms are the construct-failed shape: there is no
                // real app behind the thread, so it needs new VMs too.
                None => {
                    self.note_failure(id, state_now);
                    self.reprovision_and_restore(id)
                }
                Some(flags) if flags.len() < n_vms => {
                    self.note_failure(id, state_now);
                    self.reprovision_and_restore(id)
                }
                // §6.3 case 2: host reachable, some procs dead —
                // restart in place from the previous checkpoint
                Some(flags) if flags.iter().any(|&ok| !ok) => {
                    self.note_failure(id, state_now);
                    self.restart(id, None)
                }
                // host answered all-healthy: ERROR apps still take the
                // §5.3 passive-recovery restart; RUNNING apps were a
                // transient blip (or already recovered) — leave them be
                Some(_) if state_now == Some(AppState::Error) => self.restart(id, None),
                Some(_) => {
                    self.release_recovery(id);
                    continue;
                }
            };
            match result {
                Ok(_) => recovered.push(id),
                Err(e) => {
                    log::warn!("{id}: recovery failed: {e}");
                    // only park in ERROR if the app is still in a state
                    // we decided to recover from — a concurrent user
                    // operation (e.g. a checkpoint that raced this
                    // round) may legitimately own the lifecycle now
                    let state_now = self.state(id);
                    if matches!(
                        state_now,
                        Some(AppState::Running)
                            | Some(AppState::Restarting)
                            | Some(AppState::Error)
                    ) {
                        self.set_error(id);
                    }
                }
            }
            self.release_recovery(id);
        }
        if deferred > 0 {
            log::warn!(
                "monitor round deadline exhausted; {deferred} app(s) deferred to the next round"
            );
        }
        recovered
    }

    /// Feed one *confirmed* failure to the app's Young/Daly controller.
    /// Only fresh detections on RUNNING apps count — an ERROR app
    /// re-entering the §5.3 passive-recovery path is the same outage,
    /// and counting it again would pollute the MTBF estimate with the
    /// monitor's retry cadence.
    fn note_failure(&self, id: AppId, state_now: Option<AppState>) {
        if state_now != Some(AppState::Running) {
            return;
        }
        let now = self.now();
        let mut inner = self.shard(id);
        if let Some(rec) = inner.db.get_mut(id) {
            rec.adaptive.observe_failure(&self.cfg.adaptive, now);
        }
    }

    /// Claim `id` for recovery; false if another round holds it.
    fn claim_recovery(&self, id: AppId) -> bool {
        self.shard(id).recovering.insert(id)
    }

    fn release_recovery(&self, id: AppId) {
        self.shard(id).recovering.remove(&id);
    }

    fn set_error(&self, id: AppId) {
        let now = self.now();
        let mut inner = self.shard(id);
        if let Some(rec) = inner.db.get_mut(id) {
            if rec.lifecycle.state() != AppState::Error {
                rec.lifecycle.to(now, AppState::Error);
            }
        }
    }

    /// §6.3 case 1: the virtual cluster is unreachable — provision a
    /// fresh host (in real mode a new app thread built from the stored
    /// ASR; the analog of claiming replacement VMs) and restore it from
    /// the latest image.
    fn reprovision_and_restore(&self, id: AppId) -> Result<u64> {
        let asr = {
            let mut inner = self.shard(id);
            let rec = inner.db.get_mut(id).context("unknown coordinator")?;
            let state = rec.lifecycle.state();
            anyhow::ensure!(
                state.can_restart() || state == AppState::Restarting,
                "cannot recover in state {state}"
            );
            if state != AppState::Restarting {
                let now = self.now();
                rec.lifecycle.to(now, AppState::Restarting);
            }
            rec.asr.clone()
        };
        let factory = build_factory(&asr, &self.cfg)?;
        let handle = Arc::new(self.actors.spawn(
            &id.to_string(),
            factory,
            self.store.clone(),
            self.cfg.step_interval,
            self.cfg.delta.clone(),
        ));
        let (old, monitor) = {
            let mut inner = self.shard(id);
            // a DELETE may have raced the spawn: publishing the fresh
            // handle for a removed record would leak a stepping zombie
            // thread in the map with no path that ever removes it
            if inner.db.get(id).is_none() {
                drop(inner);
                drop(handle); // tears the just-spawned host down again
                anyhow::bail!("coordinator deleted during recovery");
            }
            let old = inner.handles.insert(id, handle.clone());
            (old, inner.monitors.get(&id).cloned())
        };
        // the tree outlives the "VMs": point its tap at the new host
        if let Some(m) = monitor {
            m.rewire(&handle);
        }
        // joins the dead host's thread if it is still around; a wedged
        // thread is detached after the bounded join grace, so recovery
        // is never held hostage by the host it is replacing
        drop(old);
        self.restart(id, None)
    }

    /// Fault injection (examples/tests): drop the application's host
    /// thread without touching its record — the real-mode analog of
    /// losing the VMs out from under a running app (§6.3 VM failure).
    pub fn kill_vm(&self, id: AppId) -> Result<()> {
        let handle = {
            let mut inner = self.shard(id);
            anyhow::ensure!(inner.db.get(id).is_some(), "unknown coordinator");
            inner.handles.remove(&id)
        };
        anyhow::ensure!(handle.is_some(), "no app thread");
        drop(handle);
        Ok(())
    }

    /// Start the Monitoring Manager thread, plus a §5.2 mode-2 ticker
    /// thread driving [`Self::periodic_round`] at the same cadence, so
    /// apps whose ASR carries `ckpt_period` self-checkpoint with zero
    /// manual POSTs (periods shorter than `monitor_period` tick at the
    /// monitor's cadence).  The ticker is a separate thread: a periodic
    /// cut may stream hundreds of MB, and failure detection must keep
    /// its PR 4 latency bounds while that happens.  Both hold only weak
    /// references; they stop when the service drops (or never start
    /// when the period is None).
    pub fn start_monitor(self: &Arc<Self>) {
        let Some(period) = self.cfg.monitor_period else { return };
        let weak: Weak<CacsService> = Arc::downgrade(self);
        std::thread::Builder::new()
            .name("cacs-monitor".into())
            .spawn(move || loop {
                std::thread::sleep(period);
                match weak.upgrade() {
                    Some(svc) => {
                        let _ = svc.monitor_round();
                    }
                    None => return,
                }
            })
            .expect("spawn monitor thread");
        let weak: Weak<CacsService> = Arc::downgrade(self);
        std::thread::Builder::new()
            .name("cacs-ckpt-ticker".into())
            .spawn(move || loop {
                std::thread::sleep(period);
                match weak.upgrade() {
                    Some(svc) => {
                        let _ = svc.periodic_round();
                    }
                    None => return,
                }
            })
            .expect("spawn checkpoint ticker thread");
        if self.cfg.capacity_slots > 0 {
            self.start_scheduler(period);
        }
    }
}

fn validate_asr(asr: &Asr) -> Result<()> {
    match &asr.workload {
        WorkloadSpec::Lu { nz, ny, nx } => {
            lu::LuConfig::new(*nz, *ny, *nx, asr.n_vms)?;
        }
        WorkloadSpec::Dmtcp1 { n } => {
            anyhow::ensure!(*n >= 1, "dmtcp1: n must be >= 1");
            anyhow::ensure!(asr.n_vms == 1, "dmtcp1 is single-process");
        }
        WorkloadSpec::Ns3 { total_bytes } => {
            anyhow::ensure!(*total_bytes >= 1, "ns3: total_bytes must be >= 1");
            anyhow::ensure!(asr.n_vms == 1, "ns3 is single-process");
        }
        WorkloadSpec::Counter { blob_bytes } => {
            anyhow::ensure!(
                *blob_bytes <= 1 << 30,
                "counter: blob_bytes must be <= 1 GiB"
            );
        }
    }
    Ok(())
}

/// Build the app factory for a workload.  PJRT is used when an artifacts
/// directory is configured and has the matching specialization; native
/// otherwise (construction happens on the app thread).
fn build_factory(asr: &Asr, cfg: &ServiceConfig) -> Result<AppFactory> {
    let workload = asr.workload.clone();
    let n_vms = asr.n_vms;
    let artifacts = cfg.artifacts_dir.clone();
    Ok(Box::new(move || -> Result<Box<dyn DistributedApp>> {
        match workload {
            WorkloadSpec::Lu { nz, ny, nx } => {
                let cfg = lu::LuConfig::new(nz, ny, nx, n_vms)?;
                let backend = match &artifacts {
                    Some(dir) => match Engine::cpu(dir) {
                        Ok(engine) => {
                            let engine = Rc::new(RefCell::new(engine));
                            match lu::Backend::pjrt(engine, &cfg) {
                                Ok(b) => b,
                                Err(e) => {
                                    log::info!("lu: PJRT unavailable ({e}); using native");
                                    lu::Backend::Native
                                }
                            }
                        }
                        Err(e) => {
                            log::info!("lu: engine init failed ({e}); using native");
                            lu::Backend::Native
                        }
                    },
                    None => lu::Backend::Native,
                };
                Ok(Box::new(lu::LuApp::new(cfg, backend)))
            }
            WorkloadSpec::Dmtcp1 { n } => {
                if let Some(dir) = &artifacts {
                    if let Ok(engine) = Engine::cpu(dir) {
                        let engine = Rc::new(RefCell::new(engine));
                        if let Ok(app) = Dmtcp1App::pjrt(engine, n) {
                            return Ok(Box::new(app));
                        }
                    }
                }
                Ok(Box::new(Dmtcp1App::native(n)))
            }
            WorkloadSpec::Ns3 { total_bytes } => {
                let cfg = ns3::Ns3Config {
                    total_bytes,
                    trace_cap: 16 * 1024 * 1024,
                    ..ns3::Ns3Config::default()
                };
                Ok(Box::new(ns3::Ns3App::new(cfg)))
            }
            WorkloadSpec::Counter { blob_bytes } => {
                Ok(Box::new(CounterApp::new(n_vms, blob_bytes)))
            }
        }
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::fault::FaultStore;
    use crate::storage::mem::MemStore;

    fn svc() -> Arc<CacsService> {
        svc_with(|cfg| cfg)
    }

    fn svc_with(f: impl FnOnce(ServiceConfig) -> ServiceConfig) -> Arc<CacsService> {
        let cfg = f(ServiceConfig { monitor_period: None, ..ServiceConfig::default() });
        CacsService::new(Arc::new(MemStore::new()), cfg)
    }

    /// Bounded poll on observable state instead of bare sleeps.
    fn wait_until(what: &str, f: impl Fn() -> bool) {
        for _ in 0..400 {
            if f() {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        panic!("timed out waiting for {what}");
    }

    fn wait_progress(svc: &CacsService, id: AppId, min_iter: u64) {
        wait_until(&format!("app {id} to reach iteration {min_iter}"), || {
            svc.info(id)
                .map(|j| j.get("iteration").as_u64().unwrap_or(0) >= min_iter)
                .unwrap_or(false)
        });
    }

    /// Wait for the hook of `proc` to report unhealthy (kill injection
    /// lands at the next step barrier, not synchronously).
    fn wait_unhealthy(svc: &CacsService, id: AppId, proc: usize) {
        wait_until(&format!("app {id} proc {proc} to report unhealthy"), || {
            svc.health(id).map(|h| !h[proc]).unwrap_or(false)
        });
    }

    #[test]
    fn submit_runs_and_lists() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d1", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        assert_eq!(svc.state(id), Some(AppState::Running));
        wait_progress(&svc, id, 5);
        let list = svc.list();
        assert_eq!(list.len(), 1);
        assert_eq!(list[0].get("state").as_str(), Some("RUNNING"));
        svc.delete(id).unwrap();
        assert!(svc.list().is_empty());
    }

    #[test]
    fn validation_rejects_bad_asrs() {
        let svc = svc();
        // lu with odd slabs
        assert!(svc
            .submit(Asr::new("bad", WorkloadSpec::Lu { nz: 12, ny: 8, nx: 8 }, 4))
            .is_err());
        // multi-vm dmtcp1
        assert!(svc
            .submit(Asr::new("bad", WorkloadSpec::Dmtcp1 { n: 8 }, 2))
            .is_err());
        assert!(svc.list().is_empty());
    }

    #[test]
    fn checkpoint_restart_cycle() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 128 }, 1))
            .unwrap();
        wait_progress(&svc, id, 10);
        let ck = svc.checkpoint(id).unwrap();
        assert_eq!(ck.seq, 1);
        assert!(ck.total_bytes > 0);
        assert_eq!(svc.state(id), Some(AppState::Running));
        wait_progress(&svc, id, ck.iteration + 10);
        let used = svc.restart(id, None).unwrap();
        assert_eq!(used, 1);
        assert_eq!(svc.state(id), Some(AppState::Running));
        let cks = svc.checkpoints(id).unwrap();
        assert_eq!(cks.len(), 1);
    }

    #[test]
    fn failure_recovery_via_monitor_round() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("lu", WorkloadSpec::Lu { nz: 4, ny: 8, nx: 8 }, 2))
            .unwrap();
        wait_progress(&svc, id, 2);
        svc.checkpoint(id).unwrap();
        svc.kill_proc(id, 1).unwrap();
        wait_unhealthy(&svc, id, 1);
        assert_eq!(svc.health(id).unwrap(), vec![true, false]);
        // unhealthy + reachable -> §6.3 case 2: restart in place
        let report = svc.health_report(id).unwrap();
        assert_eq!(report.unhealthy, vec![1]);
        assert!(!report.needs_new_vms());
        let recovered = svc.monitor_round();
        assert_eq!(recovered, vec![id]);
        assert_eq!(svc.health(id).unwrap(), vec![true, true]);
        assert_eq!(svc.state(id), Some(AppState::Running));
    }

    #[test]
    fn failure_without_checkpoint_errors() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 32 }, 1))
            .unwrap();
        wait_progress(&svc, id, 2);
        svc.kill_proc(id, 0).unwrap();
        wait_unhealthy(&svc, id, 0);
        svc.monitor_round();
        assert_eq!(svc.state(id), Some(AppState::Error));
    }

    #[test]
    fn vm_failure_reprovisions_and_restores() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 5);
        let ck = svc.checkpoint(id).unwrap();
        svc.kill_vm(id).unwrap();
        // unreachable -> §6.3 case 1: re-provision + restore
        let report = svc.health_report(id).unwrap();
        assert_eq!(report.unreachable, vec![0]);
        assert!(report.needs_new_vms());
        let recovered = svc.monitor_round();
        assert_eq!(recovered, vec![id]);
        assert_eq!(svc.state(id), Some(AppState::Running));
        assert_eq!(svc.health(id).unwrap(), vec![true]);
        // the fresh host resumed from the checkpoint, not from scratch
        let j = svc.info(id).unwrap();
        assert!(j.get("iteration").as_u64().unwrap() >= ck.iteration);
    }

    #[test]
    fn vm_failure_without_checkpoint_errors() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 32 }, 1))
            .unwrap();
        wait_progress(&svc, id, 2);
        svc.kill_vm(id).unwrap();
        svc.monitor_round();
        assert_eq!(svc.state(id), Some(AppState::Error));
    }

    #[test]
    fn error_recovery_roundtrips_through_lifecycle() {
        // §5.3 passive recovery in the real driver: with auto-recovery
        // off the monitor parks the app in ERROR; a later restart walks
        // ERROR → RESTARTING → RUNNING
        let svc = svc_with(|cfg| ServiceConfig { auto_recover: false, ..cfg });
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 3);
        svc.checkpoint(id).unwrap();
        svc.kill_proc(id, 0).unwrap();
        wait_unhealthy(&svc, id, 0);
        assert!(svc.monitor_round().is_empty());
        assert_eq!(svc.state(id), Some(AppState::Error));
        svc.restart(id, None).unwrap();
        assert_eq!(svc.state(id), Some(AppState::Running));
        assert_eq!(svc.health(id).unwrap(), vec![true]);
    }

    #[test]
    fn monitor_auto_recovers_error_state_apps() {
        // with auto-recovery on, an app parked in ERROR (here: its host
        // thread was lost after a checkpoint existed) is picked up by a
        // later monitor round via ERROR → RESTARTING
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 3);
        svc.checkpoint(id).unwrap();
        // force ERROR directly: checkpointing with the host gone fails
        svc.kill_vm(id).unwrap();
        assert!(svc.checkpoint(id).is_err());
        assert_eq!(svc.state(id), Some(AppState::Error));
        let recovered = svc.monitor_round();
        assert_eq!(recovered, vec![id]);
        assert_eq!(svc.state(id), Some(AppState::Running));
    }

    #[test]
    fn image_upload_download_roundtrip() {
        let svc_a = svc();
        let svc_b = svc();
        let a = svc_a
            .submit(Asr::new("src", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc_a, a, 5);
        let ck = svc_a.checkpoint(a).unwrap();
        let img = svc_a.download_image(a, ck.seq, 0).unwrap();
        assert!(!img.is_empty());

        // §5.3 cloning: new coordinator on the destination + upload + restart
        let b = svc_b
            .submit(Asr::new("dst", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        svc_b.upload_image(b, 7, 0, &img).unwrap();
        let used = svc_b.restart(b, Some(7)).unwrap();
        assert_eq!(used, 7);
        // destination resumed from the source's iteration
        let j = svc_b.info(b).unwrap();
        assert!(j.get("iteration").as_u64().unwrap() >= ck.iteration);
    }

    #[test]
    fn upload_after_delete_is_clean() {
        // the §5.4 DELETE / upload race, deterministic edge: uploading
        // to an already-deleted coordinator fails gracefully (no panic)
        // and leaves nothing in the store
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 16 }, 1))
            .unwrap();
        svc.delete(id).unwrap();
        let err = svc.upload_image(id, 1, 0, b"DCKPfake").unwrap_err();
        assert!(err.to_string().contains("unknown coordinator"), "{err}");
        assert!(svc.store().list(&format!("{id}/")).unwrap().is_empty());
    }

    #[test]
    fn migration_ticket_flow_and_abort() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 3);
        let ticket = svc.begin_migration(id).unwrap();
        assert_eq!(svc.state(id), Some(AppState::Migrating));
        // the app is claimed: no second migration, no user checkpoint
        assert!(matches!(
            svc.begin_migration(id),
            Err(MigrateStartError::BadState(AppState::Migrating))
        ));
        assert!(svc.checkpoint(id).is_err());
        // quiesce + checkpoint at the frozen cut
        let (frozen, _) = ticket.handle.quiesce().unwrap();
        let report = ticket
            .handle
            .checkpoint(ticket.seq, ticket.with_overhead)
            .unwrap();
        assert_eq!(report.iteration, frozen);
        let ck = svc.record_migration_ckpt(id, &report).unwrap();
        assert_eq!(ck.seq, ticket.seq);
        // a failed transfer rolls back: RUNNING again, stepping resumes
        svc.abort_migration(id);
        assert_eq!(svc.state(id), Some(AppState::Running));
        wait_progress(&svc, id, frozen + 2);
    }

    #[test]
    fn complete_migration_terminates_source_and_empties_store() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 3);
        let ticket = svc.begin_migration(id).unwrap();
        ticket.handle.quiesce().unwrap();
        let report = ticket.handle.checkpoint(ticket.seq, false).unwrap();
        svc.record_migration_ckpt(id, &report).unwrap();
        svc.complete_migration(id, "10.0.0.9:7070/coordinators/app-42".into())
            .unwrap();
        assert_eq!(svc.state(id), Some(AppState::Terminated));
        assert!(svc.store().list(&format!("{id}/")).unwrap().is_empty());
        let j = svc.info(id).unwrap();
        assert_eq!(
            j.get("migrated_to").as_str(),
            Some("10.0.0.9:7070/coordinators/app-42")
        );
        // the tombstone is inert: no checkpoint, no restart, no re-migrate
        assert!(svc.checkpoint(id).is_err());
        assert!(svc.begin_migration(id).is_err());
        // and a user DELETE still removes it entirely
        svc.delete(id).unwrap();
        assert!(svc.info(id).is_err());
    }

    #[test]
    fn checkpoint_requires_running() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 16 }, 1))
            .unwrap();
        svc.pause(id).unwrap(); // paused apps are still RUNNING state-wise
        svc.checkpoint(id).unwrap();
        svc.delete(id).unwrap();
        assert!(svc.checkpoint(id).is_err());
    }

    #[test]
    fn factory_failed_app_is_never_reported_healthy() {
        // the "dead app reports healthy" hole: a construct-failed host
        // answers Health with no flags; v1's health_report mapped that
        // to all-healthy, so the monitor never saw the dead app
        let svc = svc();
        let id = svc
            .submit_with_factory(
                Asr::new("doa", WorkloadSpec::Dmtcp1 { n: 8 }, 1),
                Box::new(|| anyhow::bail!("factory exploded")),
            )
            .unwrap();
        // the legacy bool view pads to n_vms with false
        assert_eq!(svc.health(id).unwrap(), vec![false]);
        // the tree reports every proc unreachable
        let report = svc.health_report(id).unwrap();
        assert_eq!(report.unreachable, vec![0]);
        assert!(!report.all_healthy());
        // no checkpoint exists, so the monitor parks it in ERROR rather
        // than leaving it invisibly "healthy"
        let recovered = svc.monitor_round();
        assert!(recovered.is_empty());
        assert_eq!(svc.state(id), Some(AppState::Error));
    }

    #[test]
    fn delete_checkpoint_keeps_record_when_store_fails() {
        // the store-error paths of DELETE /checkpoints/:seq, injected
        // via the composable storage::fault::FaultStore
        let store = Arc::new(FaultStore::wrapping(MemStore::new(), 11));
        let svc = CacsService::new(
            store.clone(),
            ServiceConfig { monitor_period: None, ..ServiceConfig::default() },
        );
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 2);
        let ck = svc.checkpoint(id).unwrap();
        store.arm_delete_failures(0); // refuse before anything is deleted
        let err = svc.delete_checkpoint(id, ck.seq).unwrap_err();
        assert!(err.to_string().contains("store delete"), "{err}");
        // v1 dropped the record before the store call: a store error
        // orphaned the images out of GET /checkpoints.  With the image
        // set untouched, the record must survive so the checkpoint
        // stays visible and retryable.
        assert_eq!(svc.checkpoints(id).unwrap().len(), 1);
        assert!(!store.list(&format!("{id}/")).unwrap().is_empty());
        store.disarm_deletes(); // retry: everything goes away
        assert_eq!(svc.delete_checkpoint(id, ck.seq).unwrap(), 1);
        assert!(svc.checkpoints(id).unwrap().is_empty());
        assert!(store.list(&format!("{id}/")).unwrap().is_empty());
    }

    #[test]
    fn partially_failed_delete_drops_the_torn_record() {
        // a store failure mid-set tears the checkpoint: it must not stay
        // listed as restorable (recovery would restore a corrupt set),
        // but the leftover images stay reachable for a retried delete
        let store = Arc::new(FaultStore::wrapping(MemStore::new(), 12));
        let svc = CacsService::new(
            store.clone(),
            ServiceConfig { monitor_period: None, ..ServiceConfig::default() },
        );
        let id = svc
            .submit(Asr::new("lu", WorkloadSpec::Lu { nz: 4, ny: 8, nx: 8 }, 2))
            .unwrap();
        wait_progress(&svc, id, 2);
        let ck = svc.checkpoint(id).unwrap();
        assert_eq!(ck.per_proc_bytes.len(), 2);
        store.arm_delete_failures(1); // first image deletes, the second fails
        assert!(svc.delete_checkpoint(id, ck.seq).is_err());
        assert!(
            svc.checkpoints(id).unwrap().is_empty(),
            "a torn checkpoint must not stay listed as restorable"
        );
        assert_eq!(store.list(&format!("{id}/")).unwrap().len(), 1);
        store.disarm_deletes();
        // retrying still cleans the leftover image out of the store
        assert_eq!(svc.delete_checkpoint(id, ck.seq).unwrap(), 1);
        assert!(store.list(&format!("{id}/")).unwrap().is_empty());
    }

    /// No recorded cut's `base_seq` may point at a missing seq.
    fn assert_no_dangling_bases(svc: &CacsService, id: AppId) {
        let cks = svc.checkpoints(id).unwrap();
        let seqs: BTreeSet<u64> =
            cks.iter().filter_map(|j| j.get("seq").as_u64()).collect();
        for j in &cks {
            if let Some(base) = j.get("base_seq").as_u64() {
                assert!(
                    seqs.contains(&base),
                    "checkpoint {:?} chains to missing base {base}",
                    j.get("seq").as_u64()
                );
            }
        }
    }

    #[test]
    fn delete_checkpoint_refused_while_cut_in_flight() {
        // interleaving 1 of the ticker/DELETE race: the cut already owns
        // the lifecycle — deleting any cut now could strand the delta
        // the cut is about to commit, so the DELETE must be refused
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 2);
        let ck = svc.checkpoint(id).unwrap();
        assert!(svc.force_state(id, AppState::Checkpointing));
        let err = svc.delete_checkpoint(id, ck.seq).unwrap_err();
        assert!(err.to_string().contains("in flight"), "{err}");
        assert!(svc.force_state(id, AppState::Running));
        // record and images are untouched; the DELETE is retryable
        assert_eq!(svc.checkpoints(id).unwrap().len(), 1);
        assert_eq!(svc.delete_checkpoint(id, ck.seq).unwrap(), 1);
        assert_no_dangling_bases(&svc, id);
    }

    #[test]
    fn delete_latest_cut_racing_periodic_cut_never_dangles() {
        // interleaving 2: the DELETE wins the lifecycle check and the
        // cut starts while the store delete is still in flight (slowed
        // here by FaultStore latency).  The host digests are reset
        // BEFORE the store delete — FIFO on the host command queue —
        // so the racing cut re-roots a full image instead of emitting
        // a delta chained to the cut being deleted.
        let store = Arc::new(FaultStore::wrapping(MemStore::new(), 13));
        let svc = CacsService::new(
            store.clone(),
            ServiceConfig { monitor_period: None, ..ServiceConfig::default() },
        );
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 256 }, 1))
            .unwrap();
        wait_progress(&svc, id, 2);
        let a = svc.checkpoint(id).unwrap();
        assert!(a.base_seq.is_none());
        let b = svc.checkpoint(id).unwrap();
        store.set_latency(Duration::from_millis(150));
        let svc2 = svc.clone();
        let deleter = std::thread::spawn(move || svc2.delete_checkpoint(id, b.seq));
        std::thread::sleep(Duration::from_millis(30));
        // the §5.2 ticker's cut, racing the in-flight DELETE.  Whichever
        // side won the lifecycle check, the recorded chains must stay
        // closed under base_seq.
        let c = svc.checkpoint(id);
        let deleted = deleter.join().unwrap();
        store.set_latency(Duration::ZERO);
        assert_no_dangling_bases(&svc, id);
        if deleted.is_ok() {
            // the racing cut must have re-rooted off the reset digests
            if let Ok(c) = &c {
                assert_ne!(c.base_seq, Some(b.seq), "cut chained to a deleted base");
            }
        }
        // every surviving chain is still restorable
        svc.restart(id, None).unwrap();
        assert_eq!(svc.state(id), Some(AppState::Running));
    }

    #[test]
    fn adaptive_interval_reported_and_reschedules_ticker() {
        // Young/Daly end-to-end in real mode: a periodic cut feeds the
        // controller, the ticker reschedules off the live interval, and
        // GET /coordinators/:id reports the interval and its inputs
        let svc = svc_with(|cfg| ServiceConfig {
            adaptive: AdaptiveCkptConfig { enabled: true, ..Default::default() },
            ..cfg
        });
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1).with_period(0.01))
            .unwrap();
        wait_progress(&svc, id, 2);
        wait_until("a periodic cut", || !svc.periodic_round().is_empty());
        let j = svc.info(id).unwrap();
        let a = j.get("adaptive");
        assert_eq!(a.get("enabled").as_bool(), Some(true));
        let live = a.get("ckpt_period_live").as_f64().unwrap();
        assert!(live >= 5.0, "live interval {live} below the clamp floor");
        assert!(a.get("cut_cost_ewma").as_f64().unwrap() > 0.0);
        assert_eq!(a.get("failures_observed").as_u64(), Some(0));
        // the ticker now waits the controller's interval (seconds), not
        // the ASR's 10 ms: an immediate next round has nothing due
        assert!(svc.periodic_round().is_empty());
    }

    #[test]
    fn confirmed_failures_feed_the_mtbf_estimate() {
        let svc = svc_with(|cfg| ServiceConfig {
            adaptive: AdaptiveCkptConfig { enabled: true, ..Default::default() },
            ..cfg
        });
        let id = svc
            .submit(Asr::new("lu", WorkloadSpec::Lu { nz: 4, ny: 8, nx: 8 }, 2))
            .unwrap();
        wait_progress(&svc, id, 2);
        svc.checkpoint(id).unwrap();
        svc.kill_proc(id, 1).unwrap();
        wait_unhealthy(&svc, id, 1);
        assert_eq!(svc.monitor_round(), vec![id]);
        let j = svc.info(id).unwrap();
        assert_eq!(
            j.get("adaptive").get("failures_observed").as_u64(),
            Some(1),
            "the confirmed §6.3 failure must reach the controller"
        );
    }

    #[test]
    fn submit_spawn_does_not_hold_the_service_lock() {
        // v1 held the service lock across AppHandle::spawn, so one slow
        // spawn stalled every other REST call; the spawn phase now runs
        // off-lock (the test seam sleeps inside it)
        let svc = svc_with(|cfg| ServiceConfig {
            submit_spawn_delay: Duration::from_millis(400),
            ..cfg
        });
        let svc2 = svc.clone();
        let submitter = std::thread::spawn(move || {
            svc2.submit(Asr::new("slow", WorkloadSpec::Dmtcp1 { n: 16 }, 1))
                .unwrap()
        });
        std::thread::sleep(Duration::from_millis(100)); // let it enter the spawn phase
        let t0 = Instant::now();
        let _ = svc.list();
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_millis(200),
            "list() blocked {elapsed:?} behind a slow submit spawn"
        );
        let id = submitter.join().unwrap();
        wait_until("submitted app to run", || {
            svc.state(id) == Some(AppState::Running)
        });
    }

    #[test]
    fn delete_racing_submit_tears_down_cleanly() {
        // a §5.4 DELETE landing between submit's record insert and its
        // off-lock spawn: the submit must fail and leave nothing behind
        let svc = svc_with(|cfg| ServiceConfig {
            submit_spawn_delay: Duration::from_millis(300),
            ..cfg
        });
        let svc2 = svc.clone();
        let submitter = std::thread::spawn(move || {
            svc2.submit(Asr::new("doomed", WorkloadSpec::Dmtcp1 { n: 16 }, 1))
        });
        wait_until("record to appear", || !svc.app_ids().is_empty());
        let id = svc.app_ids()[0];
        svc.delete(id).unwrap();
        let res = submitter.join().unwrap();
        assert!(res.is_err(), "submit must fail when its record was deleted mid-spawn");
        assert!(svc.app_ids().is_empty());
        assert!(svc.list().is_empty());
    }

    #[test]
    fn throttled_healthy_app_is_not_torn_down() {
        // a step throttle far above heartbeat_hop must not read as a
        // wedged host: the host loop waits on its command queue between
        // steps, so probes are answered mid-throttle, and the monitor
        // leaves the (perfectly healthy) app alone
        let svc = svc_with(|cfg| ServiceConfig {
            step_interval: Duration::from_millis(300),
            ..cfg
        });
        let id = svc
            .submit(Asr::new("slowstep", WorkloadSpec::Dmtcp1 { n: 32 }, 1))
            .unwrap();
        std::thread::sleep(Duration::from_millis(100)); // inside a throttle wait
        let report = svc.health_report(id).unwrap();
        assert!(report.all_healthy(), "throttled app misread as down: {report:?}");
        svc.checkpoint(id).unwrap(); // give recovery something to (wrongly) use
        let recovered = svc.monitor_round();
        assert!(recovered.is_empty(), "healthy throttled app was recovered: {recovered:?}");
        assert_eq!(svc.state(id), Some(AppState::Running));
    }

    #[test]
    fn health_status_mid_checkpoint_serves_last_verdict_not_false_outage() {
        // while the data plane owns the host thread (a checkpoint can
        // block the command queue for minutes), a live probe would time
        // out and misreport a healthy app as a total outage — the
        // endpoint must serve the last completed verdict instead
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 2);
        assert!(svc.health_report(id).unwrap().all_healthy()); // caches a live verdict
        assert!(svc.force_state(id, AppState::Checkpointing));
        let status = svc.health_status(id).unwrap();
        assert!(!status.live, "mid-checkpoint health must not be a live probe");
        assert_eq!(status.state, AppState::Checkpointing);
        assert!(
            status.report.all_healthy(),
            "busy app must not read as an outage: {:?}",
            status.report
        );
        assert!(svc.force_state(id, AppState::Running));
        assert!(svc.health_status(id).unwrap().live);
    }

    #[test]
    fn failed_checkpoint_does_not_burn_a_seq() {
        // v1 incremented next_ckpt_seq before the pipeline ran, so a
        // failed attempt left a permanent gap; delta chains make the
        // seq space worth keeping contiguous
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 2);
        let c1 = svc.checkpoint(id).unwrap();
        assert_eq!(c1.seq, 1);
        svc.kill_vm(id).unwrap();
        assert!(svc.checkpoint(id).is_err());
        assert_eq!(svc.state(id), Some(AppState::Error));
        let recovered = svc.monitor_round();
        assert_eq!(recovered, vec![id]);
        let c2 = svc.checkpoint(id).unwrap();
        assert_eq!(c2.seq, 2, "failed attempt must not leave a seq gap");
    }

    #[test]
    fn service_checkpoints_go_delta_after_the_first_cut() {
        let svc = svc_with(|cfg| ServiceConfig {
            delta: DeltaPolicy { chunk_size: 64, ..DeltaPolicy::default() },
            ..cfg
        });
        let id = svc
            .submit(Asr::new("c", WorkloadSpec::Counter { blob_bytes: 8192 }, 2))
            .unwrap();
        wait_progress(&svc, id, 2);
        let c1 = svc.checkpoint(id).unwrap();
        assert_eq!(c1.kind(), "full");
        wait_progress(&svc, id, c1.iteration + 2);
        let c2 = svc.checkpoint(id).unwrap();
        assert_eq!(c2.kind(), "delta");
        assert_eq!(c2.base_seq, Some(c1.seq));
        assert!(c2.delta_bytes > 0);
        assert!(
            c2.total_bytes < c1.total_bytes / 4,
            "delta cut {} vs full {}",
            c2.total_bytes,
            c1.total_bytes
        );
        // restart resolves the chain (and re-roots the next cut)
        let used = svc.restart(id, None).unwrap();
        assert_eq!(used, c2.seq);
        let c3 = svc.checkpoint(id).unwrap();
        assert_eq!(c3.kind(), "full", "post-restore cut must re-root the chain");
    }

    #[test]
    fn periodic_round_cuts_and_prunes_without_manual_posts() {
        let svc = svc_with(|cfg| ServiceConfig {
            delta: DeltaPolicy {
                chunk_size: 64,
                max_chain: 2,
                ..DeltaPolicy::default()
            },
            ckpt_keep: 2,
            ..cfg
        });
        // zero manual checkpoint calls from here on
        let id = svc
            .submit(
                Asr::new("p", WorkloadSpec::Counter { blob_bytes: 4096 }, 1)
                    .with_period(0.005),
            )
            .unwrap();
        wait_progress(&svc, id, 1);
        let mut pruned_and_plenty = false;
        for _ in 0..400 {
            svc.periodic_round();
            let cks = svc.checkpoints(id).unwrap();
            let min_seq = cks.iter().filter_map(|c| c.get("seq").as_u64()).min();
            if cks.len() >= 4 && min_seq.map(|s| s > 1).unwrap_or(false) {
                pruned_and_plenty = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(3));
        }
        assert!(pruned_and_plenty, "periodic cuts never accumulated + pruned");
        let cks = svc.checkpoints(id).unwrap();
        // both kinds appear, and every delta names its base
        let kinds: Vec<&str> =
            cks.iter().filter_map(|c| c.get("kind").as_str()).collect();
        assert!(kinds.contains(&"full") && kinds.contains(&"delta"), "{kinds:?}");
        for c in &cks {
            if c.get("kind").as_str() == Some("delta") {
                assert!(c.get("base_seq").as_u64().is_some());
            }
        }
        // pruned images are really gone from the store
        assert!(svc
            .store()
            .list(&format!("{id}/ckpt-1/"))
            .unwrap()
            .is_empty());
        // the surviving chain restores
        svc.restart(id, None).unwrap();
        assert_eq!(svc.state(id), Some(AppState::Running));
    }

    #[test]
    fn periodic_round_skips_busy_and_non_periodic_apps() {
        let svc = svc();
        // no period → never ticked
        let plain = svc
            .submit(Asr::new("plain", WorkloadSpec::Dmtcp1 { n: 32 }, 1))
            .unwrap();
        // periodic app held busy in CHECKPOINTING is skipped, not errored
        let busy = svc
            .submit(Asr::new("busy", WorkloadSpec::Dmtcp1 { n: 32 }, 1).with_period(0.001))
            .unwrap();
        wait_progress(&svc, busy, 1);
        assert!(svc.force_state(busy, AppState::Checkpointing));
        std::thread::sleep(Duration::from_millis(5));
        assert!(svc.periodic_round().is_empty());
        assert!(svc.checkpoints(plain).unwrap().is_empty());
        assert!(svc.checkpoints(busy).unwrap().is_empty());
        assert_eq!(svc.state(busy), Some(AppState::Checkpointing));
        // released, the next due tick cuts
        assert!(svc.force_state(busy, AppState::Running));
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(svc.periodic_round(), vec![busy]);
        assert_eq!(svc.checkpoints(busy).unwrap().len(), 1);
    }

    #[test]
    fn deleting_the_base_of_a_chain_is_refused_until_dependents_go() {
        // a delta cut advertised as restorable must stay restorable:
        // its base cannot be deleted out from under it
        let svc = svc_with(|cfg| ServiceConfig {
            delta: DeltaPolicy { chunk_size: 64, ..DeltaPolicy::default() },
            ..cfg
        });
        let id = svc
            .submit(Asr::new("c", WorkloadSpec::Counter { blob_bytes: 4096 }, 1))
            .unwrap();
        wait_progress(&svc, id, 2);
        let c1 = svc.checkpoint(id).unwrap();
        wait_progress(&svc, id, c1.iteration + 1);
        let c2 = svc.checkpoint(id).unwrap();
        assert_eq!(c2.base_seq, Some(c1.seq));
        let err = svc.delete_checkpoint(id, c1.seq).unwrap_err().to_string();
        assert!(err.contains("base of delta"), "{err}");
        // the chain is intact: both cuts listed, the delta restores
        assert_eq!(svc.checkpoints(id).unwrap().len(), 2);
        svc.restart(id, Some(c2.seq)).unwrap();
        // dependents-first order works
        svc.delete_checkpoint(id, c2.seq).unwrap();
        svc.delete_checkpoint(id, c1.seq).unwrap();
        assert!(svc.checkpoints(id).unwrap().is_empty());
    }

    #[test]
    fn deleting_the_latest_checkpoint_re_roots_the_chain() {
        let svc = svc_with(|cfg| ServiceConfig {
            delta: DeltaPolicy { chunk_size: 64, ..DeltaPolicy::default() },
            ..cfg
        });
        let id = svc
            .submit(Asr::new("c", WorkloadSpec::Counter { blob_bytes: 4096 }, 1))
            .unwrap();
        wait_progress(&svc, id, 2);
        let c1 = svc.checkpoint(id).unwrap();
        wait_progress(&svc, id, c1.iteration + 1);
        let c2 = svc.checkpoint(id).unwrap();
        assert_eq!(c2.kind(), "delta");
        // delete the newest cut: the host tracker's digests describe
        // it, so the next cut must re-root instead of chaining to a
        // deleted base
        svc.delete_checkpoint(id, c2.seq).unwrap();
        wait_progress(&svc, id, c2.iteration + 1);
        let c3 = svc.checkpoint(id).unwrap();
        assert_eq!(c3.kind(), "full", "chain must re-root after the base was deleted");
        svc.restart(id, None).unwrap();
    }

    #[test]
    fn wedged_host_detected_within_budget_and_recovered() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 2);
        svc.checkpoint(id).unwrap();
        svc.wedge_vm(id).unwrap();
        wait_until("wedge to take effect", || svc.health(id).is_err());
        // control-plane read degrades to the cached record promptly —
        // v1 hung GET /coordinators/:id for the 120 s call timeout
        let t0 = Instant::now();
        let j = svc.info(id).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5), "info took {:?}", t0.elapsed());
        assert_eq!(j.get("state").as_str(), Some("RUNNING"));
        // the tree reports the host unreachable within the heartbeat
        // budget (plus resolve-wave slack), not after 120 s
        let status = svc.health_status(id).unwrap();
        assert_eq!(status.report.unreachable, vec![0]);
        assert!(
            status.rtt < status.budget * 4 + Duration::from_millis(500),
            "detection rtt {:?} vs budget {:?}",
            status.rtt,
            status.budget
        );
        // recovery replaces the wedged host and restores from the image
        let recovered = svc.monitor_round();
        assert_eq!(recovered, vec![id]);
        assert_eq!(svc.state(id), Some(AppState::Running));
        assert_eq!(svc.health(id).unwrap(), vec![true]);
    }
}

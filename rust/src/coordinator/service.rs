//! Real-mode CACS service: the Fig 1 managers over real threads, real
//! storage and real (PJRT-executed) workloads.
//!
//! * Application Manager — [`CacsService::submit`] / [`CacsService::restart`]
//!   / [`CacsService::delete`], enforcing the Fig 2 lifecycle.
//! * Cloud Manager — in real mode the "virtual cluster" is the
//!   application host thread ([`super::appthread`]); provisioning is
//!   construction of the workload (PJRT artifact compilation plays the
//!   role of VM provisioning).
//! * Checkpoint Manager — stateless over any [`ObjectStore`] (§6.2),
//!   including streaming image upload/download; cross-CACS migration is
//!   a first-class operation (§5.3) driven by [`super::migrate`] over
//!   the `begin/record/abort/complete` plumbing here.
//! * Monitoring Manager — a background thread turning every
//!   application's hook results + host reachability into a structured
//!   [`HealthReport`] and driving both §6.3 recovery cases: unreachable
//!   hosts are re-provisioned and restored from the last image (case 1),
//!   unhealthy processes restart in place (case 2).  Apps parked in
//!   ERROR with a usable checkpoint are picked up via the §5.3 passive
//!   recovery path (ERROR → RESTARTING).

use crate::coordinator::appthread::{AppFactory, AppHandle};
use crate::coordinator::db::Db;
use crate::coordinator::lifecycle::AppState;
use crate::coordinator::types::{AppRecord, Asr, CkptRecord, WorkloadSpec};
use crate::dckpt::service as ckptsvc;
use crate::dckpt::DistributedApp;
use crate::monitor::HealthReport;
use crate::runtime::Engine;
use crate::storage::ObjectStore;
use crate::util::ids::{AppId, CkptId};
use crate::util::json::Json;
use crate::workloads::{dmtcp1::Dmtcp1App, lu, ns3};
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

/// Service configuration.
#[derive(Clone)]
pub struct ServiceConfig {
    /// AOT artifacts directory; enables the PJRT backend when the
    /// matching artifact exists (falls back to native otherwise).
    pub artifacts_dir: Option<PathBuf>,
    /// Throttle between workload steps (zero = run hot).
    pub step_interval: Duration,
    /// Pad images with the modelled DMTCP runtime overhead.
    pub with_runtime_overhead: bool,
    /// Health-monitoring period; None disables the monitor thread.
    pub monitor_period: Option<Duration>,
    /// Recover automatically from the latest checkpoint on failure.
    pub auto_recover: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            artifacts_dir: None,
            step_interval: Duration::from_millis(1),
            with_runtime_overhead: false,
            monitor_period: Some(Duration::from_millis(200)),
            auto_recover: true,
        }
    }
}

/// Why a migration could not start (the REST layer maps these to
/// 404 / 409 — anything later in the flow is a transfer failure).
#[derive(Debug)]
pub enum MigrateStartError {
    /// No such coordinator (404).
    UnknownCoordinator,
    /// The lifecycle refuses `RUNNING → MIGRATING` right now, e.g. a
    /// checkpoint or another migration is in flight (409).
    BadState(AppState),
    /// The record exists but its host thread is gone (409 — recovery
    /// owns the app until it is RUNNING again).
    NoAppThread,
}

impl std::fmt::Display for MigrateStartError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MigrateStartError::UnknownCoordinator => write!(f, "unknown coordinator"),
            MigrateStartError::BadState(s) => write!(f, "cannot migrate in state {s}"),
            MigrateStartError::NoAppThread => write!(f, "no app thread"),
        }
    }
}

impl std::error::Error for MigrateStartError {}

/// Everything the migration orchestrator needs after claiming the app:
/// the host-thread handle (for quiesce + checkpoint off-lock), the ASR
/// to clone onto the destination, and the reserved checkpoint seq.
pub(crate) struct MigrationTicket {
    pub handle: Arc<AppHandle>,
    pub seq: u64,
    pub asr: Asr,
    pub with_overhead: bool,
}

struct Inner {
    db: Db,
    // Arc so bulk operations (checkpoint/restore image transfers, health
    // round-trips) can clone the handle out and run WITHOUT the service
    // lock — the Monitoring Manager must stay live while images move
    handles: BTreeMap<AppId, Arc<AppHandle>>,
}

/// The service.  Share via `Arc`; [`start_monitor`](CacsService::start_monitor)
/// runs the Monitoring Manager until the service drops.
pub struct CacsService {
    cfg: ServiceConfig,
    store: Arc<dyn ObjectStore>,
    inner: Mutex<Inner>,
    epoch: Instant,
}

impl CacsService {
    pub fn new(store: Arc<dyn ObjectStore>, cfg: ServiceConfig) -> Arc<CacsService> {
        Arc::new(CacsService {
            cfg,
            store,
            inner: Mutex::new(Inner { db: Db::new(), handles: BTreeMap::new() }),
            epoch: Instant::now(),
        })
    }

    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    pub fn store(&self) -> &Arc<dyn ObjectStore> {
        &self.store
    }

    /// POST /coordinators (§5.1).
    pub fn submit(&self, asr: Asr) -> Result<AppId> {
        validate_asr(&asr)?;
        let now = self.now();
        let factory = build_factory(&asr, &self.cfg)?;
        let mut inner = self.inner.lock().unwrap();
        let id = inner.db.ids.app();
        let mut rec = AppRecord::new(id, asr, now, 0);
        // real mode: provisioning is thread + workload construction
        rec.lifecycle.to(now, AppState::Provisioning);
        let handle = AppHandle::spawn(
            &id.to_string(),
            factory,
            self.store.clone(),
            self.cfg.step_interval,
        );
        rec.lifecycle.to(self.now(), AppState::Ready);
        rec.lifecycle.to(self.now(), AppState::Running);
        inner.db.insert(rec);
        inner.handles.insert(id, Arc::new(handle));
        Ok(id)
    }

    /// Clone the app's host-thread handle out of the lock (bulk calls on
    /// it must not serialize the whole service).
    fn handle(&self, id: AppId) -> Option<Arc<AppHandle>> {
        self.inner.lock().unwrap().handles.get(&id).cloned()
    }

    /// GET /coordinators.
    pub fn list(&self) -> Vec<Json> {
        let inner = self.inner.lock().unwrap();
        inner.db.iter().map(|r| r.to_json()).collect()
    }

    /// GET /coordinators/:id (with live progress attached).
    pub fn info(&self, id: AppId) -> Result<Json> {
        let progress = self.handle(id).and_then(|h| h.progress().ok());
        let inner = self.inner.lock().unwrap();
        let rec = inner.db.get(id).context("unknown coordinator")?;
        let mut j = rec.to_json();
        if let Some((iter, metric)) = progress {
            j.set("iteration", iter.into());
            if metric.is_finite() {
                j.set("metric", metric.into());
            }
        }
        Ok(j)
    }

    /// POST /coordinators/:id/checkpoints (§5.2 mode 1).
    pub fn checkpoint(&self, id: AppId) -> Result<CkptRecord> {
        let seq = {
            let mut inner = self.inner.lock().unwrap();
            let rec = inner.db.get_mut(id).context("unknown coordinator")?;
            anyhow::ensure!(
                rec.lifecycle.state().can_checkpoint(),
                "cannot checkpoint in state {}",
                rec.lifecycle.state()
            );
            let seq = rec.next_ckpt_seq;
            rec.next_ckpt_seq += 1;
            let now = self.now();
            rec.lifecycle.to(now, AppState::Checkpointing);
            seq
        };
        // drive the image pipeline WITHOUT the service lock (it may move
        // hundreds of MB; list/health/monitor must stay live).  Any
        // failure from here on (including a missing app thread) must
        // land the lifecycle in ERROR — the v1 `?` early-return left it
        // stuck in CHECKPOINTING
        let outcome = match self.handle(id) {
            Some(handle) => handle.checkpoint(seq, self.cfg.with_runtime_overhead),
            None => Err(anyhow::anyhow!("no app thread")),
        };
        let mut inner = self.inner.lock().unwrap();
        let now = self.now();
        let Some(rec) = inner.db.get_mut(id) else {
            drop(inner);
            // a §5.4 DELETE raced the transfer: the record (and the rest
            // of the stored images) is gone — remove the images this
            // checkpoint just wrote so nothing is orphaned in the store
            let _ = ckptsvc::delete_checkpoint(self.store.as_ref(), &id.to_string(), seq);
            anyhow::bail!("coordinator deleted during checkpoint");
        };
        match outcome {
            Ok(report) => {
                rec.lifecycle.to(now, AppState::Running);
                let ck = CkptRecord {
                    id: CkptId(seq),
                    seq,
                    taken_at: now,
                    iteration: report.iteration,
                    total_bytes: report.total_bytes(),
                    per_proc_bytes: report.image_bytes.clone(),
                };
                rec.ckpts.push(ck.clone());
                Ok(ck)
            }
            Err(e) => {
                rec.lifecycle.to(now, AppState::Error);
                Err(e)
            }
        }
    }

    /// GET /coordinators/:id/checkpoints.
    pub fn checkpoints(&self, id: AppId) -> Result<Vec<Json>> {
        let inner = self.inner.lock().unwrap();
        let rec = inner.db.get(id).context("unknown coordinator")?;
        Ok(rec.ckpts.iter().map(|c| c.to_json()).collect())
    }

    /// POST /coordinators/:id/checkpoints/:seq — restart (§5.3).
    pub fn restart(&self, id: AppId, seq: Option<u64>) -> Result<u64> {
        {
            let mut inner = self.inner.lock().unwrap();
            let rec = inner.db.get_mut(id).context("unknown coordinator")?;
            let now = self.now();
            anyhow::ensure!(
                rec.lifecycle.state().can_restart()
                    || rec.lifecycle.state() == AppState::Restarting,
                "cannot restart in state {}",
                rec.lifecycle.state()
            );
            if rec.lifecycle.state() != AppState::Restarting {
                rec.lifecycle.to(now, AppState::Restarting);
            }
        }
        // restore runs without the service lock; a missing app thread is
        // a restore failure, not a `?` early return — the lifecycle must
        // land in ERROR, not stay RESTARTING
        let result = match self.handle(id) {
            Some(handle) => handle.restore(seq),
            None => Err(anyhow::anyhow!("no app thread")),
        };
        let mut inner = self.inner.lock().unwrap();
        let now = self.now();
        let rec = inner.db.get_mut(id).context("unknown coordinator")?;
        match result {
            Ok(used) => {
                rec.lifecycle.to(now, AppState::Running);
                Ok(used)
            }
            Err(e) => {
                rec.lifecycle.to(now, AppState::Error);
                Err(e)
            }
        }
    }

    /// DELETE /coordinators/:id/checkpoints/:seq.
    pub fn delete_checkpoint(&self, id: AppId, seq: u64) -> Result<usize> {
        let mut inner = self.inner.lock().unwrap();
        let rec = inner.db.get_mut(id).context("unknown coordinator")?;
        rec.ckpts.retain(|c| c.seq != seq);
        drop(inner);
        ckptsvc::delete_checkpoint(self.store.as_ref(), &id.to_string(), seq)
    }

    /// DELETE /coordinators/:id (§5.4: remove DB entry, stored images,
    /// release resources).
    ///
    /// The record leaves the database *before* the store purge: an
    /// [`upload_image`](Self::upload_image) racing this call re-checks
    /// the record after its store write and, finding it gone, removes
    /// its own key — whichever side runs last cleans up, so no orphan
    /// can survive the race in either order.
    pub fn delete(&self, id: AppId) -> Result<()> {
        let handle = {
            let mut inner = self.inner.lock().unwrap();
            let rec = inner.db.get_mut(id).context("unknown coordinator")?;
            let now = self.now();
            rec.lifecycle.to(now, AppState::Terminating);
            rec.lifecycle.to(now, AppState::Terminated);
            inner.db.remove(id);
            inner.handles.remove(&id)
        };
        drop(handle); // joins the app thread when last ref (releases the "VMs")
        let _ = ckptsvc::delete_all(self.store.as_ref(), &id.to_string());
        Ok(())
    }

    /// Upload one checkpoint image (migration receive path, §5.3:
    /// "n POST requests are sent to the corresponding checkpoints
    /// resource to upload a set of checkpoint images").
    pub fn upload_image(&self, id: AppId, seq: u64, proc: usize, data: &[u8]) -> Result<()> {
        self.upload_image_stream(id, seq, proc, &mut &data[..]).map(|_| ())
    }

    /// Streaming variant of [`upload_image`](Self::upload_image): the
    /// body flows straight into the store's
    /// [`crate::storage::PutWriter`] — the REST layer feeds it the
    /// (chunk-decoded) request body, so an image is never materialized
    /// as one buffer on the receive side.  Returns the byte count.
    pub fn upload_image_stream(
        &self,
        id: AppId,
        seq: u64,
        proc: usize,
        body: &mut dyn std::io::Read,
    ) -> Result<u64> {
        {
            let inner = self.inner.lock().unwrap();
            anyhow::ensure!(inner.db.get(id).is_some(), "unknown coordinator");
        }
        let key = ckptsvc::image_key(&id.to_string(), seq, proc);
        // the transfer runs without the service lock
        let n = {
            let mut w = self
                .store
                .put_writer(&key)
                .map_err(|e| anyhow::anyhow!("store put {key}: {e}"))?;
            std::io::copy(body, &mut w).with_context(|| format!("store put {key}"))?;
            w.finish().map_err(|e| anyhow::anyhow!("store put {key}: {e}"))?
        };
        // register/refresh the checkpoint record — re-checking the
        // record: a §5.4 DELETE may have raced the transfer (v1 called
        // `.unwrap()` here and panicked the REST worker).  The record
        // is removed before the DELETE's store purge, so when it is
        // gone we remove the just-written orphan ourselves.
        let mut inner = self.inner.lock().unwrap();
        let now = self.now();
        let Some(rec) = inner.db.get_mut(id) else {
            drop(inner);
            let _ = self.store.delete(&key);
            anyhow::bail!("coordinator deleted during upload");
        };
        if let Some(ck) = rec.ckpts.iter_mut().find(|c| c.seq == seq) {
            while ck.per_proc_bytes.len() <= proc {
                ck.per_proc_bytes.push(0);
            }
            ck.per_proc_bytes[proc] = n;
            ck.total_bytes = ck.per_proc_bytes.iter().sum();
        } else {
            rec.ckpts.push(CkptRecord {
                id: CkptId(seq),
                seq,
                taken_at: now,
                iteration: 0,
                total_bytes: n,
                per_proc_bytes: vec![n],
            });
            rec.next_ckpt_seq = rec.next_ckpt_seq.max(seq + 1);
        }
        Ok(n)
    }

    /// Download one checkpoint image (migration send path).
    pub fn download_image(&self, id: AppId, seq: u64, proc: usize) -> Result<Vec<u8>> {
        let key = ckptsvc::image_key(&id.to_string(), seq, proc);
        self.store
            .get(&key)
            .map_err(|e| anyhow::anyhow!("store get: {e}"))
    }

    // --- §5.3 cross-CACS migration plumbing (driven by
    // [`super::migrate::migrate`], which owns the orchestration) -------

    /// Atomically claim the app for migration: validate the lifecycle
    /// (only RUNNING may migrate — anything else is a 409 at the REST
    /// layer), move it to MIGRATING and reserve the checkpoint
    /// sequence.  The caller quiesces and checkpoints via the returned
    /// handle *without* the service lock.
    pub(crate) fn begin_migration(
        &self,
        id: AppId,
    ) -> Result<MigrationTicket, MigrateStartError> {
        let now = self.now();
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let Some(rec) = inner.db.get_mut(id) else {
            return Err(MigrateStartError::UnknownCoordinator);
        };
        let state = rec.lifecycle.state();
        if !state.can_migrate() {
            return Err(MigrateStartError::BadState(state));
        }
        let Some(handle) = inner.handles.get(&id).cloned() else {
            return Err(MigrateStartError::NoAppThread);
        };
        rec.lifecycle.to(now, AppState::Migrating);
        let seq = rec.next_ckpt_seq;
        rec.next_ckpt_seq += 1;
        Ok(MigrationTicket {
            handle,
            seq,
            asr: rec.asr.clone(),
            with_overhead: self.cfg.with_runtime_overhead,
        })
    }

    /// Register the checkpoint the migration took (the MIGRATING state
    /// means no user checkpoint can race this sequence number).
    pub(crate) fn record_migration_ckpt(
        &self,
        id: AppId,
        report: &ckptsvc::CheckpointReport,
    ) -> Result<CkptRecord> {
        let now = self.now();
        let mut inner = self.inner.lock().unwrap();
        let rec = inner
            .db
            .get_mut(id)
            .context("coordinator deleted during migration")?;
        let ck = CkptRecord {
            id: CkptId(report.seq),
            seq: report.seq,
            taken_at: now,
            iteration: report.iteration,
            total_bytes: report.total_bytes(),
            per_proc_bytes: report.image_bytes.clone(),
        };
        rec.ckpts.push(ck.clone());
        Ok(ck)
    }

    /// A migration failed before the source was touched: roll the
    /// lifecycle back to RUNNING and resume stepping.  (A concurrent
    /// DELETE may have removed the record; then there is nothing to
    /// roll back.)
    pub(crate) fn abort_migration(&self, id: AppId) {
        let handle = {
            let now = self.now();
            let mut inner = self.inner.lock().unwrap();
            let inner = &mut *inner;
            if let Some(rec) = inner.db.get_mut(id) {
                if rec.lifecycle.state() == AppState::Migrating {
                    rec.lifecycle.to(now, AppState::Running);
                }
            }
            inner.handles.get(&id).cloned()
        };
        if let Some(h) = handle {
            h.resume();
        }
    }

    /// The clone is confirmed RUNNING on the destination: terminate the
    /// source (§5.3 "migration = clone + terminate source").  The host
    /// thread is joined, the stored images purged, and a TERMINATED
    /// tombstone with `migrated_to` kept in the database so the move
    /// stays auditable (a user DELETE removes the tombstone too).
    pub(crate) fn complete_migration(&self, id: AppId, migrated_to: String) -> Result<()> {
        let handle = {
            let now = self.now();
            let mut inner = self.inner.lock().unwrap();
            let inner = &mut *inner;
            let rec = inner
                .db
                .get_mut(id)
                .context("coordinator deleted during migration")?;
            rec.migrated_to = Some(migrated_to);
            rec.lifecycle.to(now, AppState::Terminating);
            inner.handles.remove(&id)
        };
        drop(handle); // joins the host thread — releases the "VMs"
        let _ = ckptsvc::delete_all(self.store.as_ref(), &id.to_string());
        let now = self.now();
        let mut inner = self.inner.lock().unwrap();
        if let Some(rec) = inner.db.get_mut(id) {
            rec.lifecycle.to(now, AppState::Terminated);
        }
        Ok(())
    }

    /// Test seam: drive a (legal) lifecycle transition directly, e.g.
    /// to hold an app in CHECKPOINTING while probing REST guards.
    #[cfg(test)]
    pub(crate) fn force_state(&self, id: AppId, next: AppState) -> bool {
        let now = self.now();
        let mut inner = self.inner.lock().unwrap();
        inner
            .db
            .get_mut(id)
            .map(|r| r.lifecycle.to(now, next))
            .unwrap_or(false)
    }

    /// Health snapshot (the REST layer exposes this for diagnostics).
    pub fn health(&self, id: AppId) -> Result<Vec<bool>> {
        let handle = self.handle(id).context("unknown coordinator")?;
        handle.health()
    }

    /// Fault injection (examples/tests): kill process `proc`.
    pub fn kill_proc(&self, id: AppId, proc: usize) -> Result<()> {
        let inner = self.inner.lock().unwrap();
        let handle = inner.handles.get(&id).context("unknown coordinator")?;
        handle.kill_proc(proc);
        Ok(())
    }

    /// Pause/resume (oversubscription example).
    pub fn pause(&self, id: AppId) -> Result<()> {
        let inner = self.inner.lock().unwrap();
        inner.handles.get(&id).context("unknown coordinator")?.pause();
        Ok(())
    }

    pub fn resume(&self, id: AppId) -> Result<()> {
        let inner = self.inner.lock().unwrap();
        inner.handles.get(&id).context("unknown coordinator")?.resume();
        Ok(())
    }

    /// App ids currently registered.
    pub fn app_ids(&self) -> Vec<AppId> {
        self.inner.lock().unwrap().db.ids_sorted()
    }

    pub fn state(&self, id: AppId) -> Option<AppState> {
        self.inner.lock().unwrap().db.get(id).map(|r| r.lifecycle.state())
    }

    /// One §6.3 health report for an app, synthesized from the
    /// per-process hook results (*unhealthy*) and host-thread
    /// reachability (*unreachable* — in real mode the app thread plays
    /// the virtual cluster, so losing it is the VM-failure case).
    pub fn health_report(&self, id: AppId) -> Result<HealthReport> {
        let (n, handle) = {
            let inner = self.inner.lock().unwrap();
            let rec = inner.db.get(id).context("unknown coordinator")?;
            (rec.asr.n_vms, inner.handles.get(&id).cloned())
        };
        // the hook round-trip runs without the service lock
        let report = match handle {
            None => HealthReport { unhealthy: vec![], unreachable: (0..n).collect() },
            Some(h) => match h.health() {
                Ok(flags) => HealthReport {
                    unhealthy: flags
                        .iter()
                        .enumerate()
                        .filter(|&(_, &ok)| !ok)
                        .map(|(i, _)| i)
                        .collect(),
                    unreachable: vec![],
                },
                Err(_) => HealthReport { unhealthy: vec![], unreachable: (0..n).collect() },
            },
        };
        Ok(report)
    }

    /// One monitoring round over all apps (§6.3); returns the ids that
    /// entered recovery.  Called by the monitor thread and directly by
    /// tests.
    ///
    /// Two recovery cases per the paper: an *unreachable* virtual
    /// cluster is re-provisioned and restored from the last image
    /// ([`Self::reprovision_and_restore`]); *unhealthy* processes on a
    /// reachable cluster restart in place ([`Self::restart`]).  Apps
    /// already in ERROR that have a usable checkpoint take the §5.3
    /// passive-recovery path (ERROR → RESTARTING).
    pub fn monitor_round(&self) -> Vec<AppId> {
        let mut recovered = vec![];
        for id in self.app_ids() {
            let (state, has_ckpt) = {
                let inner = self.inner.lock().unwrap();
                let Some(rec) = inner.db.get(id) else { continue };
                (rec.lifecycle.state(), rec.latest_ckpt().is_some())
            };
            if state != AppState::Running && state != AppState::Error {
                continue;
            }
            let Ok(report) = self.health_report(id) else { continue };
            if state == AppState::Running && report.all_healthy() {
                continue;
            }
            if state == AppState::Error && !self.cfg.auto_recover {
                continue; // a user DELETE or manual restart must resolve it
            }
            if !report.all_healthy() {
                log::warn!(
                    "{id}: unhealthy {:?} unreachable {:?}",
                    report.unhealthy,
                    report.unreachable
                );
            }
            if !self.cfg.auto_recover || !has_ckpt {
                self.set_error(id);
                continue;
            }
            let result = if report.needs_new_vms() {
                // §6.3 case 1: VM failure — new "VMs" + restore
                self.reprovision_and_restore(id)
            } else {
                // §6.3 case 2: application failure — restart in place
                // from the previous checkpoint
                self.restart(id, None)
            };
            match result {
                Ok(_) => recovered.push(id),
                Err(e) => {
                    log::warn!("{id}: recovery failed: {e}");
                    // only park in ERROR if the app is still in a state
                    // we decided to recover from — a concurrent user
                    // operation (e.g. a checkpoint that raced this
                    // round) may legitimately own the lifecycle now
                    let state_now = self.state(id);
                    if matches!(
                        state_now,
                        Some(AppState::Running)
                            | Some(AppState::Restarting)
                            | Some(AppState::Error)
                    ) {
                        self.set_error(id);
                    }
                }
            }
        }
        recovered
    }

    fn set_error(&self, id: AppId) {
        let now = self.now();
        let mut inner = self.inner.lock().unwrap();
        if let Some(rec) = inner.db.get_mut(id) {
            if rec.lifecycle.state() != AppState::Error {
                rec.lifecycle.to(now, AppState::Error);
            }
        }
    }

    /// §6.3 case 1: the virtual cluster is unreachable — provision a
    /// fresh host (in real mode a new app thread built from the stored
    /// ASR; the analog of claiming replacement VMs) and restore it from
    /// the latest image.
    fn reprovision_and_restore(&self, id: AppId) -> Result<u64> {
        let asr = {
            let mut inner = self.inner.lock().unwrap();
            let rec = inner.db.get_mut(id).context("unknown coordinator")?;
            let state = rec.lifecycle.state();
            anyhow::ensure!(
                state.can_restart() || state == AppState::Restarting,
                "cannot recover in state {state}"
            );
            if state != AppState::Restarting {
                let now = self.now();
                rec.lifecycle.to(now, AppState::Restarting);
            }
            rec.asr.clone()
        };
        let factory = build_factory(&asr, &self.cfg)?;
        let handle = AppHandle::spawn(
            &id.to_string(),
            factory,
            self.store.clone(),
            self.cfg.step_interval,
        );
        let old = {
            let mut inner = self.inner.lock().unwrap();
            inner.handles.insert(id, Arc::new(handle))
        };
        drop(old); // joins the dead host's thread, if it is still around
        self.restart(id, None)
    }

    /// Fault injection (examples/tests): drop the application's host
    /// thread without touching its record — the real-mode analog of
    /// losing the VMs out from under a running app (§6.3 VM failure).
    pub fn kill_vm(&self, id: AppId) -> Result<()> {
        let handle = {
            let mut inner = self.inner.lock().unwrap();
            anyhow::ensure!(inner.db.get(id).is_some(), "unknown coordinator");
            inner.handles.remove(&id)
        };
        anyhow::ensure!(handle.is_some(), "no app thread");
        drop(handle);
        Ok(())
    }

    /// Start the Monitoring Manager thread.  Holds only a weak
    /// reference; stops when the service drops or the period is None.
    pub fn start_monitor(self: &Arc<Self>) {
        let Some(period) = self.cfg.monitor_period else { return };
        let weak: Weak<CacsService> = Arc::downgrade(self);
        std::thread::Builder::new()
            .name("cacs-monitor".into())
            .spawn(move || loop {
                std::thread::sleep(period);
                match weak.upgrade() {
                    Some(svc) => {
                        let _ = svc.monitor_round();
                    }
                    None => return,
                }
            })
            .expect("spawn monitor thread");
    }
}

fn validate_asr(asr: &Asr) -> Result<()> {
    match &asr.workload {
        WorkloadSpec::Lu { nz, ny, nx } => {
            lu::LuConfig::new(*nz, *ny, *nx, asr.n_vms)?;
        }
        WorkloadSpec::Dmtcp1 { n } => {
            anyhow::ensure!(*n >= 1, "dmtcp1: n must be >= 1");
            anyhow::ensure!(asr.n_vms == 1, "dmtcp1 is single-process");
        }
        WorkloadSpec::Ns3 { total_bytes } => {
            anyhow::ensure!(*total_bytes >= 1, "ns3: total_bytes must be >= 1");
            anyhow::ensure!(asr.n_vms == 1, "ns3 is single-process");
        }
    }
    Ok(())
}

/// Build the app factory for a workload.  PJRT is used when an artifacts
/// directory is configured and has the matching specialization; native
/// otherwise (construction happens on the app thread).
fn build_factory(asr: &Asr, cfg: &ServiceConfig) -> Result<AppFactory> {
    let workload = asr.workload.clone();
    let n_vms = asr.n_vms;
    let artifacts = cfg.artifacts_dir.clone();
    Ok(Box::new(move || -> Result<Box<dyn DistributedApp>> {
        match workload {
            WorkloadSpec::Lu { nz, ny, nx } => {
                let cfg = lu::LuConfig::new(nz, ny, nx, n_vms)?;
                let backend = match &artifacts {
                    Some(dir) => match Engine::cpu(dir) {
                        Ok(engine) => {
                            let engine = Rc::new(RefCell::new(engine));
                            match lu::Backend::pjrt(engine, &cfg) {
                                Ok(b) => b,
                                Err(e) => {
                                    log::info!("lu: PJRT unavailable ({e}); using native");
                                    lu::Backend::Native
                                }
                            }
                        }
                        Err(e) => {
                            log::info!("lu: engine init failed ({e}); using native");
                            lu::Backend::Native
                        }
                    },
                    None => lu::Backend::Native,
                };
                Ok(Box::new(lu::LuApp::new(cfg, backend)))
            }
            WorkloadSpec::Dmtcp1 { n } => {
                if let Some(dir) = &artifacts {
                    if let Ok(engine) = Engine::cpu(dir) {
                        let engine = Rc::new(RefCell::new(engine));
                        if let Ok(app) = Dmtcp1App::pjrt(engine, n) {
                            return Ok(Box::new(app));
                        }
                    }
                }
                Ok(Box::new(Dmtcp1App::native(n)))
            }
            WorkloadSpec::Ns3 { total_bytes } => {
                let cfg = ns3::Ns3Config {
                    total_bytes,
                    trace_cap: 16 * 1024 * 1024,
                    ..ns3::Ns3Config::default()
                };
                Ok(Box::new(ns3::Ns3App::new(cfg)))
            }
        }
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::mem::MemStore;

    fn svc() -> Arc<CacsService> {
        svc_with(|cfg| cfg)
    }

    fn svc_with(f: impl FnOnce(ServiceConfig) -> ServiceConfig) -> Arc<CacsService> {
        let cfg = f(ServiceConfig { monitor_period: None, ..ServiceConfig::default() });
        CacsService::new(Arc::new(MemStore::new()), cfg)
    }

    /// Bounded poll on observable state instead of bare sleeps.
    fn wait_until(what: &str, f: impl Fn() -> bool) {
        for _ in 0..400 {
            if f() {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        panic!("timed out waiting for {what}");
    }

    fn wait_progress(svc: &CacsService, id: AppId, min_iter: u64) {
        wait_until(&format!("app {id} to reach iteration {min_iter}"), || {
            svc.info(id)
                .map(|j| j.get("iteration").as_u64().unwrap_or(0) >= min_iter)
                .unwrap_or(false)
        });
    }

    /// Wait for the hook of `proc` to report unhealthy (kill injection
    /// lands at the next step barrier, not synchronously).
    fn wait_unhealthy(svc: &CacsService, id: AppId, proc: usize) {
        wait_until(&format!("app {id} proc {proc} to report unhealthy"), || {
            svc.health(id).map(|h| !h[proc]).unwrap_or(false)
        });
    }

    #[test]
    fn submit_runs_and_lists() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d1", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        assert_eq!(svc.state(id), Some(AppState::Running));
        wait_progress(&svc, id, 5);
        let list = svc.list();
        assert_eq!(list.len(), 1);
        assert_eq!(list[0].get("state").as_str(), Some("RUNNING"));
        svc.delete(id).unwrap();
        assert!(svc.list().is_empty());
    }

    #[test]
    fn validation_rejects_bad_asrs() {
        let svc = svc();
        // lu with odd slabs
        assert!(svc
            .submit(Asr::new("bad", WorkloadSpec::Lu { nz: 12, ny: 8, nx: 8 }, 4))
            .is_err());
        // multi-vm dmtcp1
        assert!(svc
            .submit(Asr::new("bad", WorkloadSpec::Dmtcp1 { n: 8 }, 2))
            .is_err());
        assert!(svc.list().is_empty());
    }

    #[test]
    fn checkpoint_restart_cycle() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 128 }, 1))
            .unwrap();
        wait_progress(&svc, id, 10);
        let ck = svc.checkpoint(id).unwrap();
        assert_eq!(ck.seq, 1);
        assert!(ck.total_bytes > 0);
        assert_eq!(svc.state(id), Some(AppState::Running));
        wait_progress(&svc, id, ck.iteration + 10);
        let used = svc.restart(id, None).unwrap();
        assert_eq!(used, 1);
        assert_eq!(svc.state(id), Some(AppState::Running));
        let cks = svc.checkpoints(id).unwrap();
        assert_eq!(cks.len(), 1);
    }

    #[test]
    fn failure_recovery_via_monitor_round() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("lu", WorkloadSpec::Lu { nz: 4, ny: 8, nx: 8 }, 2))
            .unwrap();
        wait_progress(&svc, id, 2);
        svc.checkpoint(id).unwrap();
        svc.kill_proc(id, 1).unwrap();
        wait_unhealthy(&svc, id, 1);
        assert_eq!(svc.health(id).unwrap(), vec![true, false]);
        // unhealthy + reachable -> §6.3 case 2: restart in place
        let report = svc.health_report(id).unwrap();
        assert_eq!(report.unhealthy, vec![1]);
        assert!(!report.needs_new_vms());
        let recovered = svc.monitor_round();
        assert_eq!(recovered, vec![id]);
        assert_eq!(svc.health(id).unwrap(), vec![true, true]);
        assert_eq!(svc.state(id), Some(AppState::Running));
    }

    #[test]
    fn failure_without_checkpoint_errors() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 32 }, 1))
            .unwrap();
        wait_progress(&svc, id, 2);
        svc.kill_proc(id, 0).unwrap();
        wait_unhealthy(&svc, id, 0);
        svc.monitor_round();
        assert_eq!(svc.state(id), Some(AppState::Error));
    }

    #[test]
    fn vm_failure_reprovisions_and_restores() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 5);
        let ck = svc.checkpoint(id).unwrap();
        svc.kill_vm(id).unwrap();
        // unreachable -> §6.3 case 1: re-provision + restore
        let report = svc.health_report(id).unwrap();
        assert_eq!(report.unreachable, vec![0]);
        assert!(report.needs_new_vms());
        let recovered = svc.monitor_round();
        assert_eq!(recovered, vec![id]);
        assert_eq!(svc.state(id), Some(AppState::Running));
        assert_eq!(svc.health(id).unwrap(), vec![true]);
        // the fresh host resumed from the checkpoint, not from scratch
        let j = svc.info(id).unwrap();
        assert!(j.get("iteration").as_u64().unwrap() >= ck.iteration);
    }

    #[test]
    fn vm_failure_without_checkpoint_errors() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 32 }, 1))
            .unwrap();
        wait_progress(&svc, id, 2);
        svc.kill_vm(id).unwrap();
        svc.monitor_round();
        assert_eq!(svc.state(id), Some(AppState::Error));
    }

    #[test]
    fn error_recovery_roundtrips_through_lifecycle() {
        // §5.3 passive recovery in the real driver: with auto-recovery
        // off the monitor parks the app in ERROR; a later restart walks
        // ERROR → RESTARTING → RUNNING
        let svc = svc_with(|cfg| ServiceConfig { auto_recover: false, ..cfg });
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 3);
        svc.checkpoint(id).unwrap();
        svc.kill_proc(id, 0).unwrap();
        wait_unhealthy(&svc, id, 0);
        assert!(svc.monitor_round().is_empty());
        assert_eq!(svc.state(id), Some(AppState::Error));
        svc.restart(id, None).unwrap();
        assert_eq!(svc.state(id), Some(AppState::Running));
        assert_eq!(svc.health(id).unwrap(), vec![true]);
    }

    #[test]
    fn monitor_auto_recovers_error_state_apps() {
        // with auto-recovery on, an app parked in ERROR (here: its host
        // thread was lost after a checkpoint existed) is picked up by a
        // later monitor round via ERROR → RESTARTING
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 3);
        svc.checkpoint(id).unwrap();
        // force ERROR directly: checkpointing with the host gone fails
        svc.kill_vm(id).unwrap();
        assert!(svc.checkpoint(id).is_err());
        assert_eq!(svc.state(id), Some(AppState::Error));
        let recovered = svc.monitor_round();
        assert_eq!(recovered, vec![id]);
        assert_eq!(svc.state(id), Some(AppState::Running));
    }

    #[test]
    fn image_upload_download_roundtrip() {
        let svc_a = svc();
        let svc_b = svc();
        let a = svc_a
            .submit(Asr::new("src", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc_a, a, 5);
        let ck = svc_a.checkpoint(a).unwrap();
        let img = svc_a.download_image(a, ck.seq, 0).unwrap();
        assert!(!img.is_empty());

        // §5.3 cloning: new coordinator on the destination + upload + restart
        let b = svc_b
            .submit(Asr::new("dst", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        svc_b.upload_image(b, 7, 0, &img).unwrap();
        let used = svc_b.restart(b, Some(7)).unwrap();
        assert_eq!(used, 7);
        // destination resumed from the source's iteration
        let j = svc_b.info(b).unwrap();
        assert!(j.get("iteration").as_u64().unwrap() >= ck.iteration);
    }

    #[test]
    fn upload_after_delete_is_clean() {
        // the §5.4 DELETE / upload race, deterministic edge: uploading
        // to an already-deleted coordinator fails gracefully (no panic)
        // and leaves nothing in the store
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 16 }, 1))
            .unwrap();
        svc.delete(id).unwrap();
        let err = svc.upload_image(id, 1, 0, b"DCKPfake").unwrap_err();
        assert!(err.to_string().contains("unknown coordinator"), "{err}");
        assert!(svc.store().list(&format!("{id}/")).unwrap().is_empty());
    }

    #[test]
    fn migration_ticket_flow_and_abort() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 3);
        let ticket = svc.begin_migration(id).unwrap();
        assert_eq!(svc.state(id), Some(AppState::Migrating));
        // the app is claimed: no second migration, no user checkpoint
        assert!(matches!(
            svc.begin_migration(id),
            Err(MigrateStartError::BadState(AppState::Migrating))
        ));
        assert!(svc.checkpoint(id).is_err());
        // quiesce + checkpoint at the frozen cut
        let (frozen, _) = ticket.handle.quiesce().unwrap();
        let report = ticket
            .handle
            .checkpoint(ticket.seq, ticket.with_overhead)
            .unwrap();
        assert_eq!(report.iteration, frozen);
        let ck = svc.record_migration_ckpt(id, &report).unwrap();
        assert_eq!(ck.seq, ticket.seq);
        // a failed transfer rolls back: RUNNING again, stepping resumes
        svc.abort_migration(id);
        assert_eq!(svc.state(id), Some(AppState::Running));
        wait_progress(&svc, id, frozen + 2);
    }

    #[test]
    fn complete_migration_terminates_source_and_empties_store() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 64 }, 1))
            .unwrap();
        wait_progress(&svc, id, 3);
        let ticket = svc.begin_migration(id).unwrap();
        ticket.handle.quiesce().unwrap();
        let report = ticket.handle.checkpoint(ticket.seq, false).unwrap();
        svc.record_migration_ckpt(id, &report).unwrap();
        svc.complete_migration(id, "10.0.0.9:7070/coordinators/app-42".into())
            .unwrap();
        assert_eq!(svc.state(id), Some(AppState::Terminated));
        assert!(svc.store().list(&format!("{id}/")).unwrap().is_empty());
        let j = svc.info(id).unwrap();
        assert_eq!(
            j.get("migrated_to").as_str(),
            Some("10.0.0.9:7070/coordinators/app-42")
        );
        // the tombstone is inert: no checkpoint, no restart, no re-migrate
        assert!(svc.checkpoint(id).is_err());
        assert!(svc.begin_migration(id).is_err());
        // and a user DELETE still removes it entirely
        svc.delete(id).unwrap();
        assert!(svc.info(id).is_err());
    }

    #[test]
    fn checkpoint_requires_running() {
        let svc = svc();
        let id = svc
            .submit(Asr::new("d", WorkloadSpec::Dmtcp1 { n: 16 }, 1))
            .unwrap();
        svc.pause(id).unwrap(); // paused apps are still RUNNING state-wise
        svc.checkpoint(id).unwrap();
        svc.delete(id).unwrap();
        assert!(svc.checkpoint(id).is_err());
    }
}

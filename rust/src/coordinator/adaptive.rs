//! Young/Daly adaptive checkpoint intervals.
//!
//! The paper's §5.2 mode-2 periodic checkpointing takes a fixed
//! `ckpt_period` from the ASR.  A fixed period is only optimal for one
//! (cut cost, failure rate) point: too short wastes work on checkpoint
//! overhead, too long loses work to failures.  The classic first-order
//! optimum (Young 1974, Daly 2006) is
//!
//! ```text
//! period* = sqrt(2 · C · MTBF)
//! ```
//!
//! where `C` is the time one cut costs the application and `MTBF` the
//! mean time between failures.  Neither input is known up front, so
//! this module is a tiny online controller: the service feeds it every
//! measured cut cost and every confirmed failure, it keeps EWMA
//! estimates of both, and [`AdaptiveCkptState::next_period`] emits a
//! clamped, output-smoothed interval.  Both drivers share it — the
//! real-mode ticker ([`super::service::CacsService::periodic_round`])
//! and the sim driver's periodic scheduler — and the live interval plus
//! its inputs are reported on `GET /coordinators/:id`.

use crate::util::json::Json;

/// Controller tuning, threaded through `ServiceConfig` / `SimParams`.
#[derive(Debug, Clone)]
pub struct AdaptiveCkptConfig {
    /// Off by default: the ASR's fixed `ckpt_period` stays authoritative.
    pub enabled: bool,
    /// Clamp floor for the emitted period (s) — a noisy MTBF estimate
    /// must never drive the service into checkpointing back-to-back.
    pub min_period: f64,
    /// Clamp ceiling (s): even on an apparently failure-free run, cuts
    /// keep happening often enough that the first failure is not a
    /// disaster.
    pub max_period: f64,
    /// EWMA smoothing factor in (0, 1] for the cut-cost and MTBF
    /// estimates and for the emitted period itself (1 = no smoothing).
    pub alpha: f64,
    /// Assumed MTBF (s) before the first failure gap is observed.
    pub default_mtbf: f64,
}

impl Default for AdaptiveCkptConfig {
    fn default() -> Self {
        AdaptiveCkptConfig {
            enabled: false,
            min_period: 5.0,
            max_period: 3600.0,
            alpha: 0.3,
            default_mtbf: 3600.0,
        }
    }
}

impl AdaptiveCkptConfig {
    /// Enabled with the default clamps (convenience for tests/benches).
    pub fn enabled() -> AdaptiveCkptConfig {
        AdaptiveCkptConfig { enabled: true, ..Default::default() }
    }
}

fn ewma(prev: Option<f64>, sample: f64, alpha: f64) -> f64 {
    match prev {
        Some(p) => p + alpha * (sample - p),
        None => sample,
    }
}

/// Per-application controller state (lives in `AppRecord` / `SimAppExt`).
#[derive(Debug, Clone, Default)]
pub struct AdaptiveCkptState {
    /// EWMA of observed per-cut cost (s); None until the first cut.
    pub cut_cost_ewma: Option<f64>,
    /// EWMA of observed failure gaps (s); None until two failures.
    pub mtbf_ewma: Option<f64>,
    /// Service-clock time of the most recent confirmed failure.
    pub last_failure_at: Option<f64>,
    /// Confirmed failures fed to the controller.
    pub failures: u64,
    /// The interval most recently emitted by [`Self::next_period`] —
    /// what the REST surface reports as the live interval.
    pub period: Option<f64>,
}

impl AdaptiveCkptState {
    /// Feed one measured checkpoint cost (seconds the cut stole from
    /// the application).
    pub fn observe_cut(&mut self, cfg: &AdaptiveCkptConfig, cost_s: f64) {
        if cost_s.is_finite() && cost_s > 0.0 {
            self.cut_cost_ewma = Some(ewma(self.cut_cost_ewma, cost_s, cfg.alpha));
        }
    }

    /// Feed one confirmed failure at service-clock time `now_s`.  The
    /// first failure only anchors the clock; from the second on, the
    /// gap between consecutive failures is an MTBF sample.
    pub fn observe_failure(&mut self, cfg: &AdaptiveCkptConfig, now_s: f64) {
        if let Some(prev) = self.last_failure_at {
            let gap = now_s - prev;
            if gap.is_finite() && gap > 0.0 {
                self.mtbf_ewma = Some(ewma(self.mtbf_ewma, gap, cfg.alpha));
            }
        }
        self.last_failure_at = Some(now_s);
        self.failures += 1;
    }

    /// The raw (unsmoothed) Young/Daly target given current estimates;
    /// None until at least one cut cost has been observed.
    pub fn target(&self, cfg: &AdaptiveCkptConfig) -> Option<f64> {
        let c = self.cut_cost_ewma?;
        let mtbf = self.mtbf_ewma.unwrap_or(cfg.default_mtbf);
        Some((2.0 * c * mtbf).sqrt().clamp(cfg.min_period, cfg.max_period))
    }

    /// Emit the next interval: the clamped Young/Daly target, smoothed
    /// against the previously emitted period so one noisy cut doesn't
    /// yank the timer around.  Falls back to `fallback` (the ASR's
    /// fixed period) until a cut cost exists or when disabled.
    pub fn next_period(&mut self, cfg: &AdaptiveCkptConfig, fallback: f64) -> f64 {
        if !cfg.enabled {
            return fallback;
        }
        let Some(raw) = self.target(cfg) else {
            return fallback;
        };
        let smoothed = ewma(self.period, raw, cfg.alpha).clamp(cfg.min_period, cfg.max_period);
        self.period = Some(smoothed);
        smoothed
    }

    /// REST reporting: the live interval and the estimates behind it.
    /// Returns None when the controller has nothing to say (disabled or
    /// no observations yet) so plain records stay clean.
    pub fn to_json(&self, cfg: &AdaptiveCkptConfig) -> Option<Json> {
        if !cfg.enabled && self.failures == 0 && self.cut_cost_ewma.is_none() {
            return None;
        }
        let mut j = Json::obj();
        j.set("enabled", cfg.enabled.into());
        if let Some(p) = self.period {
            j.set("ckpt_period_live", p.into());
        }
        if let Some(c) = self.cut_cost_ewma {
            j.set("cut_cost_ewma", c.into());
        }
        j.set("mtbf_ewma", self.mtbf_ewma.unwrap_or(cfg.default_mtbf).into());
        j.set("failures_observed", self.failures.into());
        Some(j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AdaptiveCkptConfig {
        AdaptiveCkptConfig::enabled()
    }

    #[test]
    fn disabled_controller_passes_the_fallback_through() {
        let mut st = AdaptiveCkptState::default();
        let off = AdaptiveCkptConfig::default();
        st.observe_cut(&off, 10.0);
        assert_eq!(st.next_period(&off, 120.0), 120.0);
        assert!(st.period.is_none());
    }

    #[test]
    fn no_observations_means_fallback() {
        let mut st = AdaptiveCkptState::default();
        assert_eq!(st.next_period(&cfg(), 77.0), 77.0);
    }

    #[test]
    fn young_daly_formula_with_default_mtbf() {
        let mut st = AdaptiveCkptState::default();
        let c = cfg();
        st.observe_cut(&c, 8.0);
        let want = (2.0f64 * 8.0 * c.default_mtbf).sqrt();
        assert!((st.target(&c).unwrap() - want).abs() < 1e-9);
        // first emission is the raw target (nothing to smooth against)
        assert!((st.next_period(&c, 1.0) - want).abs() < 1e-9);
    }

    #[test]
    fn mtbf_learned_from_failure_gaps() {
        let mut st = AdaptiveCkptState::default();
        let c = cfg();
        st.observe_failure(&c, 100.0);
        assert!(st.mtbf_ewma.is_none(), "one failure only anchors the clock");
        st.observe_failure(&c, 300.0);
        assert_eq!(st.mtbf_ewma, Some(200.0));
        st.observe_failure(&c, 400.0);
        // ewma: 200 + 0.3 * (100 - 200) = 170
        assert!((st.mtbf_ewma.unwrap() - 170.0).abs() < 1e-9);
        assert_eq!(st.failures, 3);
    }

    #[test]
    fn frequent_failures_shorten_the_period() {
        let c = cfg();
        let period_for_gap = |gap: f64| {
            let mut st = AdaptiveCkptState::default();
            st.observe_cut(&c, 5.0);
            let mut t = 0.0;
            for _ in 0..20 {
                st.observe_failure(&c, t);
                t += gap;
            }
            st.next_period(&c, 600.0)
        };
        let flaky = period_for_gap(60.0);
        let stable = period_for_gap(3000.0);
        assert!(
            flaky < stable,
            "more failures must mean shorter intervals: {flaky} vs {stable}"
        );
        // sqrt(2*5*60) ≈ 24.5 — well below the stable regime
        assert!(flaky < 40.0, "flaky={flaky}");
    }

    #[test]
    fn clamped_to_bounds() {
        let mut c = cfg();
        c.min_period = 30.0;
        c.max_period = 300.0;
        let mut st = AdaptiveCkptState::default();
        // microscopic cut cost + rapid failures → clamp floor
        st.observe_cut(&c, 1e-6);
        st.observe_failure(&c, 0.0);
        st.observe_failure(&c, 0.5);
        assert_eq!(st.next_period(&c, 600.0), 30.0);
        // huge cut cost, huge MTBF → clamp ceiling
        let mut st = AdaptiveCkptState::default();
        st.observe_cut(&c, 1e4);
        assert_eq!(st.next_period(&c, 600.0), 300.0);
    }

    #[test]
    fn output_is_ewma_smoothed() {
        let c = cfg();
        let mut st = AdaptiveCkptState::default();
        st.observe_cut(&c, 10.0);
        let p1 = st.next_period(&c, 600.0);
        // a sudden 100× cheaper cut moves the raw target a lot; the
        // emitted period moves only alpha of the way there
        st.cut_cost_ewma = Some(0.1);
        let raw = st.target(&c).unwrap();
        let p2 = st.next_period(&c, 600.0);
        assert!((p2 - (p1 + c.alpha * (raw - p1))).abs() < 1e-9);
        assert!(p2 < p1 && p2 > raw);
    }

    #[test]
    fn json_reports_live_interval_and_inputs() {
        let c = cfg();
        let mut st = AdaptiveCkptState::default();
        assert!(st.to_json(&AdaptiveCkptConfig::default()).is_none());
        st.observe_cut(&c, 4.0);
        st.observe_failure(&c, 10.0);
        st.observe_failure(&c, 110.0);
        let p = st.next_period(&c, 600.0);
        let j = st.to_json(&c).unwrap();
        assert_eq!(j.get("enabled").as_bool(), Some(true));
        assert!((j.get("ckpt_period_live").as_f64().unwrap() - p).abs() < 1e-9);
        assert!((j.get("cut_cost_ewma").as_f64().unwrap() - 4.0).abs() < 1e-9);
        assert!((j.get("mtbf_ewma").as_f64().unwrap() - 100.0).abs() < 1e-9);
        assert_eq!(j.get("failures_observed").as_u64(), Some(2));
    }
}

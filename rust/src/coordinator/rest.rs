//! REST API (Table 1) over the real-mode service.
//!
//! | verb + path | semantics |
//! |---|---|
//! | GET    /coordinators                      | list coordinators |
//! | POST   /coordinators                      | add a new coordinator (body = ASR) |
//! | GET    /coordinators/:id                  | coordinator info |
//! | DELETE /coordinators/:id                  | delete the coordinator (true empty 204) |
//! | POST   /coordinators/:id/migrate          | migrate to another CACS (body = `{"dst": "host:port", "precopy": bool?}`, §5.3 / Fig 5); `precopy` streams a full cut while the app runs and ships only the dirty-chunk delta at the quiesced barrier; `{"mode": "pull", "pull_from": "host:port"}` switches to the WAN-resilient destination-driven flow (resumable range fetches, CAS dedup, optional `"compress": true` zrle wire encoding, `"retry"` overrides); a pull that exhausts its retry budget answers 502 with `{error, attempts, last_offset, bytes_verified}`; 409 while a checkpoint/restart/migration is in flight |
//! | POST   /coordinators/:id/pull             | destination side of pull-mode migration: body = the source's transfer manifest; fetches, dedups and commits every image, answering the transfer stats (400 bad manifest, 404 unknown clone, 502 structured retry-exhaustion) |
//! | GET    /coordinators/:id/checkpoints      | list checkpoints — each cut says `kind` (full/delta), `base_seq` and `delta_bytes` |
//! | POST   /coordinators/:id/checkpoints      | trigger a checkpoint, **or** upload an image (octet-stream body + `x-ckpt-seq`/`x-proc-index` headers, optional `x-base-seq` for delta images; the body streams straight into the store) |
//! | GET    /coordinators/:id/checkpoints/:seq | checkpoint info; `?proc=i` downloads that image (400 for an unparsable `proc`, 404 for a missing image) — honors `Range` (206/416) and `x-cacs-accept-encoding: zrle` for resumable compressed pulls |
//! | POST   /coordinators/:id/checkpoints/:seq | restart from the checkpoint |
//! | DELETE /coordinators/:id/checkpoints/:seq | delete the checkpoint |
//! | POST   /coordinators/:id/preempt          | spot-revocation warning (§2.2 use case 4): checkpoint + swap the app out within the deadline budget (body = `{"deadline_s": f64}`, default 30); 404 unknown, 409 when the lifecycle refuses |
//! | POST   /coordinators/:id/resume           | swap a SWAPPED_OUT app back in at its parked cut (the scheduler also does this automatically as capacity returns); 404 unknown, 409 when not parked |
//!
//! Plus diagnostics the paper's CLI would expose: GET
//! /coordinators/:id/health — one §6.3 broadcast-tree heartbeat over
//! the app's monitoring tree, returning the structured report
//! (`healthy`/`unhealthy`/`unreachable`) together with its
//! detection-latency accounting (`rtt_ms`, `waves`, `budget_ms`,
//! `hop_ms`, `arity`).  The probe is bounded by the heartbeat budget,
//! so the endpoint answers fast even when the app's host thread is
//! wedged.
//!
//! The migrate endpoint drives the Fig 2 lifecycle through the
//! `MIGRATING` state: `RUNNING → MIGRATING` on entry, `MIGRATING →
//! TERMINATING → TERMINATED` once the clone runs on the destination,
//! `MIGRATING → RUNNING` if the transfer fails (the source rolls back).

use super::migrate::{self, MigrateError, PullFailure};
use super::service::CacsService;
use super::types::Asr;
use crate::storage::cas;
use crate::util::http::{ranged_response, Handler, Method, Request, Response, Server};
use crate::util::ids::AppId;
use crate::util::json::Json;
use std::sync::Arc;

/// Build the request handler for a service instance.
pub fn make_handler(svc: Arc<CacsService>) -> Handler {
    Arc::new(move |req: &mut Request| route(&svc, req))
}

/// Start the REST server (addr like "127.0.0.1:0").
pub fn serve(svc: Arc<CacsService>, addr: &str, threads: usize) -> std::io::Result<Server> {
    Server::start(addr, threads, make_handler(svc))
}

fn parse_app(seg: &str) -> Option<AppId> {
    AppId::parse(seg)
}

fn route(svc: &Arc<CacsService>, req: &mut Request) -> Response {
    // own the path: the body accessors below need `req` mutably while
    // the matched segments stay alive
    let raw_path = req.path.clone();
    let (path, query) = match raw_path.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (raw_path.as_str(), None),
    };
    let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();

    match (req.method, segs.as_slice()) {
        (Method::Get, ["coordinators"]) => {
            Response::ok_json(&Json::Arr(svc.list()))
        }
        (Method::Post, ["coordinators"]) => {
            let body = match req.json() {
                Ok(j) => j,
                Err(e) => return Response::bad_request(&e.to_string()),
            };
            match Asr::from_json(&body).and_then(|asr| svc.submit(asr)) {
                Ok(id) => Response::json(
                    201,
                    &Json::object([("id", id.to_string().into())]),
                ),
                Err(e) => Response::bad_request(&e.to_string()),
            }
        }
        (Method::Get, ["coordinators", id]) => match parse_app(id) {
            Some(id) => match svc.info(id) {
                Ok(j) => Response::ok_json(&j),
                Err(_) => Response::not_found(),
            },
            None => Response::bad_request("bad coordinator id"),
        },
        (Method::Delete, ["coordinators", id]) => match parse_app(id) {
            Some(id) => match svc.delete(id) {
                Ok(()) => Response::no_content(),
                Err(_) => Response::not_found(),
            },
            None => Response::bad_request("bad coordinator id"),
        },
        (Method::Get, ["coordinators", id, "health"]) => match parse_app(id) {
            Some(id) => match svc.health_status(id) {
                Ok(status) => Response::ok_json(&status.to_json()),
                Err(_) => Response::not_found(),
            },
            None => Response::bad_request("bad coordinator id"),
        },
        (Method::Post, ["coordinators", id, "preempt"]) => {
            let Some(id) = parse_app(id) else {
                return Response::bad_request("bad coordinator id");
            };
            // the revocation deadline rides the (optional) body
            let deadline_s = req
                .json()
                .ok()
                .and_then(|j| j.get("deadline_s").as_f64())
                .filter(|s| s.is_finite() && *s > 0.0)
                .unwrap_or(30.0);
            match svc.preempt(id, std::time::Duration::from_secs_f64(deadline_s)) {
                Ok(report) => Response::ok_json(&report.to_json()),
                Err(e) if e.to_string().contains("unknown coordinator") => {
                    Response::not_found()
                }
                Err(e) => Response::conflict(&e.to_string()),
            }
        }
        (Method::Post, ["coordinators", id, "resume"]) => {
            let Some(id) = parse_app(id) else {
                return Response::bad_request("bad coordinator id");
            };
            match svc.swap_in(id) {
                Ok(seq) => Response::ok_json(&Json::object([("resumed_from", seq.into())])),
                Err(e) if e.to_string().contains("unknown coordinator") => {
                    Response::not_found()
                }
                Err(e) => Response::conflict(&e.to_string()),
            }
        }
        (Method::Post, ["coordinators", id, "migrate"]) => {
            let Some(id) = parse_app(id) else {
                return Response::bad_request("bad coordinator id");
            };
            let body = match req.json() {
                Ok(j) => j,
                Err(e) => return Response::bad_request(&e.to_string()),
            };
            let Some(dst) = body.get("dst").as_str() else {
                return Response::bad_request(
                    "migrate needs a destination: {\"dst\": \"host:port\"}",
                );
            };
            let mode = match body.get("mode").as_str() {
                Some("pull") => {
                    let Some(pull_from) = body.get("pull_from").as_str() else {
                        return Response::bad_request(
                            "pull mode needs a source address: {\"pull_from\": \"host:port\"}",
                        );
                    };
                    let mut opts = migrate::PullOpts::new(pull_from);
                    opts.compress = body.get("compress").as_bool().unwrap_or(false);
                    opts.seed = body.get("seed").as_u64().unwrap_or(0);
                    let r = body.get("retry");
                    opts.max_attempts = r.get("max_attempts").as_u64().map(|v| v as u32);
                    opts.base_backoff_ms = r.get("base_backoff_ms").as_u64();
                    opts.max_backoff_ms = r.get("max_backoff_ms").as_u64();
                    opts.connect_timeout_ms = r.get("connect_timeout_ms").as_u64();
                    opts.attempt_timeout_ms = r.get("attempt_timeout_ms").as_u64();
                    opts.overall_deadline_ms = r.get("overall_deadline_ms").as_u64();
                    migrate::MigrateMode::Pull(opts)
                }
                Some("push") | None => migrate::MigrateMode::Push {
                    precopy: body.get("precopy").as_bool().unwrap_or(false),
                },
                Some(other) => {
                    return Response::bad_request(&format!("unknown migrate mode {other:?}"))
                }
            };
            match migrate::migrate_with(svc, id, dst, &mode) {
                Ok(report) => Response::ok_json(&report.to_json()),
                Err(MigrateError::UnknownCoordinator) => Response::not_found(),
                Err(MigrateError::Conflict(m)) => Response::conflict(&m),
                Err(MigrateError::PullExhausted(info)) => Response::json(502, &info.to_json()),
                Err(e) => Response::json(
                    502,
                    &Json::object([("error", e.to_string().into())]),
                ),
            }
        }
        (Method::Post, ["coordinators", id, "pull"]) => {
            let Some(id) = parse_app(id) else {
                return Response::bad_request("bad coordinator id");
            };
            let manifest = match req.json() {
                Ok(j) => j,
                Err(e) => return Response::bad_request(&e.to_string()),
            };
            match migrate::execute_pull(svc, id, &manifest) {
                Ok(stats) => Response::ok_json(&stats.to_json()),
                Err(PullFailure::BadManifest(m)) => Response::bad_request(&m),
                Err(PullFailure::UnknownCoordinator) => Response::not_found(),
                Err(PullFailure::Exhausted(info)) => Response::json(502, &info.to_json()),
                Err(PullFailure::Failed(e)) => Response::json(
                    502,
                    &Json::object([("error", format!("{e:#}").into())]),
                ),
            }
        }
        (Method::Get, ["coordinators", id, "checkpoints"]) => match parse_app(id) {
            Some(id) => match svc.checkpoints(id) {
                Ok(cks) => Response::ok_json(&Json::Arr(cks)),
                Err(_) => Response::not_found(),
            },
            None => Response::bad_request("bad coordinator id"),
        },
        (Method::Post, ["coordinators", id, "checkpoints"]) => {
            let Some(id) = parse_app(id) else {
                return Response::bad_request("bad coordinator id");
            };
            // image upload variant (§5.3): octet-stream + seq/proc headers
            let is_upload = req
                .headers
                .get("content-type")
                .map(|c| c.contains("octet-stream"))
                .unwrap_or(false);
            if is_upload {
                let seq = req.headers.get("x-ckpt-seq").and_then(|v| v.parse().ok());
                let proc = req.headers.get("x-proc-index").and_then(|v| v.parse().ok());
                let (Some(seq), Some(proc)) = (seq, proc) else {
                    return Response::bad_request("upload needs x-ckpt-seq and x-proc-index");
                };
                // delta chain metadata rides the x-base-seq header
                let base_seq = req.headers.get("x-base-seq").and_then(|v| v.parse().ok());
                // the body streams off the wire straight into the store
                let mut body = req.body_reader();
                return match svc.upload_image_stream(id, seq, proc, base_seq, &mut body) {
                    Ok(n) => Response::json(
                        201,
                        &Json::object([("uploaded", true.into()), ("bytes", n.into())]),
                    ),
                    Err(e) => {
                        // drain the rest of the upload so the 400 (not
                        // a connection reset) reaches the sender
                        let _ = std::io::copy(&mut body, &mut std::io::sink());
                        Response::bad_request(&e.to_string())
                    }
                };
            }
            match svc.checkpoint(id) {
                Ok(ck) => Response::json(201, &ck.to_json()),
                Err(e) => Response::bad_request(&e.to_string()),
            }
        }
        (Method::Get, ["coordinators", id, "checkpoints", seq]) => {
            let Some(id) = parse_app(id) else {
                return Response::bad_request("bad coordinator id");
            };
            let Ok(seq) = seq.parse::<u64>() else {
                return Response::bad_request("bad checkpoint seq");
            };
            // ?proc=i downloads the raw image (migration send path).
            // An unparsable proc is the caller's error (400) — falling
            // through to checkpoint-info JSON here used to hand an
            // octet-stream client a JSON body instead
            if let Some(raw) = query
                .and_then(|q| q.split('&').find_map(|kv| kv.strip_prefix("proc=")))
            {
                let Ok(proc) = raw.parse::<usize>() else {
                    return Response::bad_request("bad proc index");
                };
                return match svc.download_image(id, seq, proc) {
                    Ok(bytes) => {
                        // the pull path resumes via Range and may ask
                        // for zrle wire compression; the content-range
                        // stays in decoded byte space
                        let range = req.headers.get("range").map(|s| s.as_str());
                        let mut resp =
                            ranged_response(range, &bytes, "application/octet-stream");
                        let zrle_ok = req
                            .headers
                            .get("x-cacs-accept-encoding")
                            .map(|v| v.contains("zrle"))
                            .unwrap_or(false);
                        if zrle_ok && (resp.status == 200 || resp.status == 206) {
                            resp.body = cas::zrle_encode(&resp.body);
                            resp = resp.with_header("x-cacs-encoding", "zrle");
                        }
                        resp
                    }
                    Err(_) => Response::not_found(),
                };
            }
            match svc.checkpoints(id) {
                Ok(cks) => {
                    match cks.iter().find(|c| c.get("seq").as_u64() == Some(seq)) {
                        Some(c) => Response::ok_json(c),
                        None => Response::not_found(),
                    }
                }
                Err(_) => Response::not_found(),
            }
        }
        (Method::Post, ["coordinators", id, "checkpoints", seq]) => {
            let Some(id) = parse_app(id) else {
                return Response::bad_request("bad coordinator id");
            };
            let Ok(seq) = seq.parse::<u64>() else {
                return Response::bad_request("bad checkpoint seq");
            };
            match svc.restart(id, Some(seq)) {
                Ok(used) => Response::ok_json(&Json::object([("restarted_from", used.into())])),
                Err(e) => Response::bad_request(&e.to_string()),
            }
        }
        (Method::Delete, ["coordinators", id, "checkpoints", seq]) => {
            let Some(id) = parse_app(id) else {
                return Response::bad_request("bad coordinator id");
            };
            let Ok(seq) = seq.parse::<u64>() else {
                return Response::bad_request("bad checkpoint seq");
            };
            match svc.delete_checkpoint(id, seq) {
                Ok(n) => Response::ok_json(&Json::object([("deleted_images", n.into())])),
                Err(e) => Response::bad_request(&e.to_string()),
            }
        }
        _ => Response::not_found(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::lifecycle::AppState;
    use crate::coordinator::service::ServiceConfig;
    use crate::storage::mem::MemStore;
    use crate::util::http::Client;
    use std::time::Duration;

    fn start() -> (Server, Client, Arc<CacsService>) {
        let svc = CacsService::new(
            Arc::new(MemStore::new()),
            ServiceConfig { monitor_period: None, ..ServiceConfig::default() },
        );
        let server = serve(svc.clone(), "127.0.0.1:0", 4).unwrap();
        let client = Client::new(&server.addr().to_string());
        (server, client, svc)
    }

    fn submit_dmtcp1(client: &Client) -> String {
        let asr = Json::object([
            ("name", "d1".into()),
            ("workload", Json::object([("kind", "dmtcp1".into()), ("n", 64u64.into())])),
            ("n_vms", 1u64.into()),
        ]);
        let resp = client.post("/coordinators", &asr).unwrap();
        assert_eq!(resp.status, 201, "{:?}", String::from_utf8_lossy(&resp.body));
        resp.json().unwrap().get("id").as_str().unwrap().to_string()
    }

    /// Bounded poll on the observable REST state (no bare sleeps: the
    /// old fixed 30–50 ms naps flaked on slow machines).
    fn wait_iter(client: &Client, id: &str, min: u64) {
        for _ in 0..400 {
            let ok = client
                .get(&format!("/coordinators/{id}"))
                .ok()
                .and_then(|r| r.json().ok())
                .map(|j| {
                    j.get("state").as_str() == Some("RUNNING")
                        && j.get("iteration").as_u64().unwrap_or(0) >= min
                })
                .unwrap_or(false);
            if ok {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        panic!("{id} never reached RUNNING at iteration {min}");
    }

    #[test]
    fn table1_surface() {
        let (_server, client, _svc) = start();
        // empty list
        let resp = client.get("/coordinators").unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.json().unwrap(), Json::Arr(vec![]));

        let id = submit_dmtcp1(&client);
        wait_iter(&client, &id, 1);

        // GET /coordinators/:id
        let info = client.get(&format!("/coordinators/{id}")).unwrap();
        assert_eq!(info.status, 200);
        assert_eq!(info.json().unwrap().get("state").as_str(), Some("RUNNING"));

        // POST checkpoint
        let ck = client
            .post(&format!("/coordinators/{id}/checkpoints"), &Json::Null)
            .unwrap();
        assert_eq!(ck.status, 201);
        let seq = ck.json().unwrap().get("seq").as_u64().unwrap();

        // GET checkpoints
        let list = client.get(&format!("/coordinators/{id}/checkpoints")).unwrap();
        assert_eq!(list.json().unwrap().as_arr().unwrap().len(), 1);

        // GET one checkpoint
        let one = client
            .get(&format!("/coordinators/{id}/checkpoints/{seq}"))
            .unwrap();
        assert_eq!(one.status, 200);

        // POST restart
        let rs = client
            .post(&format!("/coordinators/{id}/checkpoints/{seq}"), &Json::Null)
            .unwrap();
        assert_eq!(rs.status, 200);
        assert_eq!(rs.json().unwrap().get("restarted_from").as_u64(), Some(seq));

        // DELETE checkpoint
        let del = client
            .delete(&format!("/coordinators/{id}/checkpoints/{seq}"))
            .unwrap();
        assert_eq!(del.status, 200);

        // DELETE coordinator: a true RFC 9110 204 — no body, no
        // entity headers
        let del = client.delete(&format!("/coordinators/{id}")).unwrap();
        assert_eq!(del.status, 204);
        assert!(del.body.is_empty());
        assert!(!del.headers.contains_key("content-type"), "{:?}", del.headers);
        assert!(!del.headers.contains_key("content-length"), "{:?}", del.headers);
        let resp = client.get(&format!("/coordinators/{id}")).unwrap();
        assert_eq!(resp.status, 404);
    }

    #[test]
    fn bad_requests() {
        let (_server, client, _svc) = start();
        assert_eq!(client.get("/nope").unwrap().status, 404);
        assert_eq!(client.get("/coordinators/app-99").unwrap().status, 404);
        assert_eq!(client.get("/coordinators/xyz").unwrap().status, 400);
        let resp = client
            .post("/coordinators", &Json::object([("name", "x".into())]))
            .unwrap();
        assert_eq!(resp.status, 400);
        let resp = client
            .post("/coordinators/app-1/checkpoints/not-a-number", &Json::Null)
            .unwrap();
        assert_eq!(resp.status, 400);
    }

    #[test]
    fn image_download_via_query() {
        let (_server, client, _svc) = start();
        let id = submit_dmtcp1(&client);
        wait_iter(&client, &id, 1);
        let ck = client
            .post(&format!("/coordinators/{id}/checkpoints"), &Json::Null)
            .unwrap();
        let seq = ck.json().unwrap().get("seq").as_u64().unwrap();
        let img = client
            .get(&format!("/coordinators/{id}/checkpoints/{seq}?proc=0"))
            .unwrap();
        assert_eq!(img.status, 200);
        assert!(img.body.starts_with(b"DCKP"));
        // missing image -> 404
        let missing = client
            .get(&format!("/coordinators/{id}/checkpoints/{seq}?proc=5"))
            .unwrap();
        assert_eq!(missing.status, 404);
    }

    #[test]
    fn malformed_proc_query_is_400_not_json_fallthrough() {
        // `?proc=abc` / `?proc=-1` used to be silently ignored, handing
        // an octet-stream caller checkpoint-info JSON with a 200
        let (_server, client, _svc) = start();
        let id = submit_dmtcp1(&client);
        wait_iter(&client, &id, 1);
        let ck = client
            .post(&format!("/coordinators/{id}/checkpoints"), &Json::Null)
            .unwrap();
        let seq = ck.json().unwrap().get("seq").as_u64().unwrap();
        for bad in ["abc", "-1", ""] {
            let resp = client
                .get(&format!("/coordinators/{id}/checkpoints/{seq}?proc={bad}"))
                .unwrap();
            assert_eq!(resp.status, 400, "proc={bad:?}: {:?}", resp.status);
        }
        // without a proc param the route still answers checkpoint info
        let info = client
            .get(&format!("/coordinators/{id}/checkpoints/{seq}"))
            .unwrap();
        assert_eq!(info.status, 200);
        assert_eq!(info.json().unwrap().get("seq").as_u64(), Some(seq));
    }

    #[test]
    fn migrate_while_checkpointing_is_409() {
        let (_server, client, svc) = start();
        let id = submit_dmtcp1(&client);
        wait_iter(&client, &id, 1);
        let app = AppId::parse(&id).unwrap();
        // hold the app in CHECKPOINTING and try to migrate it
        assert!(svc.force_state(app, AppState::Checkpointing));
        let resp = client
            .post(
                &format!("/coordinators/{id}/migrate"),
                &Json::object([("dst", "127.0.0.1:1".into())]),
            )
            .unwrap();
        assert_eq!(resp.status, 409, "{}", String::from_utf8_lossy(&resp.body));
        assert!(
            String::from_utf8_lossy(&resp.body).contains("CHECKPOINTING"),
            "{}",
            String::from_utf8_lossy(&resp.body)
        );
        // the app is untouched by the refusal
        let info = client.get(&format!("/coordinators/{id}")).unwrap();
        assert_eq!(info.json().unwrap().get("state").as_str(), Some("CHECKPOINTING"));
        assert!(svc.force_state(app, AppState::Running));
    }

    #[test]
    fn migrate_bad_requests() {
        let (_server, client, _svc) = start();
        // unknown coordinator -> 404
        let resp = client
            .post(
                "/coordinators/app-99/migrate",
                &Json::object([("dst", "127.0.0.1:1".into())]),
            )
            .unwrap();
        assert_eq!(resp.status, 404);
        // missing dst -> 400
        let id = submit_dmtcp1(&client);
        wait_iter(&client, &id, 1);
        let resp = client
            .post(&format!("/coordinators/{id}/migrate"), &Json::Null)
            .unwrap();
        assert_eq!(resp.status, 400);
        // unreachable destination -> 502, and the source rolls back to
        // RUNNING (nothing was torn down)
        let resp = client
            .post(
                &format!("/coordinators/{id}/migrate"),
                &Json::object([("dst", "127.0.0.1:1".into())]),
            )
            .unwrap();
        assert_eq!(resp.status, 502, "{}", String::from_utf8_lossy(&resp.body));
        wait_iter(&client, &id, 1);
        // ...and the failed attempt must not leak its checkpoint
        // (record or images) — retries would accumulate image sets
        let cks = client
            .get(&format!("/coordinators/{id}/checkpoints"))
            .unwrap();
        assert_eq!(cks.json().unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn panicking_app_actor_does_not_take_down_rest() {
        // satellite of the actor refactor: a panic inside one app's
        // command handler (here: its serialize hook) used to poison the
        // global service lock and 500 every later request.  The shard
        // locks recover from poisoning and the actor pool isolates the
        // panic, so REST keeps serving every other route.
        use crate::dckpt::{CounterApp, DistributedApp};

        struct PanicOnSerialize(CounterApp);
        impl DistributedApp for PanicOnSerialize {
            fn nprocs(&self) -> usize {
                self.0.nprocs()
            }
            fn step(&mut self) -> anyhow::Result<()> {
                self.0.step()
            }
            fn serialize_proc(&self, _i: usize) -> anyhow::Result<Vec<u8>> {
                panic!("serialize hook exploded")
            }
            fn restore_proc(&mut self, i: usize, payload: &[u8]) -> anyhow::Result<()> {
                self.0.restore_proc(i, payload)
            }
            fn proc_healthy(&self, i: usize) -> bool {
                self.0.proc_healthy(i)
            }
            fn kill_proc(&mut self, i: usize) {
                self.0.kill_proc(i)
            }
            fn iteration(&self) -> u64 {
                self.0.iteration()
            }
            fn metric(&self) -> f64 {
                self.0.metric()
            }
            fn kind(&self) -> &'static str {
                "panicky"
            }
        }

        let (_server, client, svc) = start();
        let healthy = submit_dmtcp1(&client);
        wait_iter(&client, &healthy, 1);
        let bad = svc
            .submit_with_factory(
                Asr::new("panicky", crate::coordinator::types::WorkloadSpec::Counter {
                    blob_bytes: 64,
                }, 1),
                Box::new(|| {
                    Ok(Box::new(PanicOnSerialize(CounterApp::new(1, 64)))
                        as Box<dyn DistributedApp>)
                }),
            )
            .unwrap();
        // the checkpoint panics inside the actor: a prompt 400, not a
        // worker hang and not a poisoned-lock panic
        let t0 = std::time::Instant::now();
        let resp = client
            .post(&format!("/coordinators/{bad}/checkpoints"), &Json::Null)
            .unwrap();
        assert_eq!(resp.status, 400, "{}", String::from_utf8_lossy(&resp.body));
        assert!(t0.elapsed() < Duration::from_secs(10));
        // REST stays fully live: list, the healthy app's info and a
        // checkpoint on it all still work
        assert_eq!(client.get("/coordinators").unwrap().status, 200);
        wait_iter(&client, &healthy, 1);
        let ck = client
            .post(&format!("/coordinators/{healthy}/checkpoints"), &Json::Null)
            .unwrap();
        assert_eq!(ck.status, 201, "{}", String::from_utf8_lossy(&ck.body));
        // the panicked app is still visible (in ERROR, per the failed
        // checkpoint's lifecycle landing), with its actor gauges served
        let info = client.get(&format!("/coordinators/{bad}")).unwrap();
        assert_eq!(info.status, 200);
        let j = info.json().unwrap();
        assert_eq!(j.get("state").as_str(), Some("ERROR"));
        assert!(j.get("actor").get("pool_workers").as_u64().unwrap() >= 1);
    }

    #[test]
    fn preempt_and_resume_endpoints() {
        let (_server, client, _svc) = start();
        let id = submit_dmtcp1(&client);
        wait_iter(&client, &id, 2);
        // a spot-revocation warning parks the app within the deadline
        let resp = client
            .post(
                &format!("/coordinators/{id}/preempt"),
                &Json::object([("deadline_s", 30.0f64.into())]),
            )
            .unwrap();
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let j = resp.json().unwrap();
        assert_eq!(j.get("met_deadline").as_bool(), Some(true));
        let seq = j.get("seq").as_u64().unwrap();
        let info = client.get(&format!("/coordinators/{id}")).unwrap();
        assert_eq!(info.json().unwrap().get("state").as_str(), Some("SWAPPED_OUT"));
        // a second warning for a parked app is a 409, an unknown app 404
        let again = client
            .post(&format!("/coordinators/{id}/preempt"), &Json::Null)
            .unwrap();
        assert_eq!(again.status, 409, "{}", String::from_utf8_lossy(&again.body));
        let nf = client.post("/coordinators/app-99/preempt", &Json::Null).unwrap();
        assert_eq!(nf.status, 404);
        let nf = client.post("/coordinators/app-99/resume", &Json::Null).unwrap();
        assert_eq!(nf.status, 404);
        // explicit resume restores at exactly the parked cut
        let resp = client
            .post(&format!("/coordinators/{id}/resume"), &Json::Null)
            .unwrap();
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        assert_eq!(resp.json().unwrap().get("resumed_from").as_u64(), Some(seq));
        wait_iter(&client, &id, 1);
        // resuming an app that is not parked is a 409
        let resp = client
            .post(&format!("/coordinators/{id}/resume"), &Json::Null)
            .unwrap();
        assert_eq!(resp.status, 409, "{}", String::from_utf8_lossy(&resp.body));
    }

    #[test]
    fn health_endpoint_reports_structured_verdict_and_latency() {
        let (_server, client, svc) = start();
        let id = submit_dmtcp1(&client);
        wait_iter(&client, &id, 1);
        let h = client.get(&format!("/coordinators/{id}/health")).unwrap();
        assert_eq!(h.status, 200);
        let j = h.json().unwrap();
        assert_eq!(j.get("healthy").as_bool(), Some(true));
        assert_eq!(j.get("unhealthy").as_arr().unwrap().len(), 0);
        assert_eq!(j.get("unreachable").as_arr().unwrap().len(), 0);
        assert_eq!(j.get("n_vms").as_u64(), Some(1));
        assert_eq!(j.get("state").as_str(), Some("RUNNING"));
        assert_eq!(j.get("live").as_bool(), Some(true));
        // detection-latency fields: a real probe ran inside its budget
        assert!(j.get("rtt_ms").as_f64().unwrap() >= 0.0);
        assert!(j.get("budget_ms").as_f64().unwrap() > 0.0);
        assert!(j.get("waves").as_u64().unwrap() >= 1);
        assert!(j.get("hop_ms").as_f64().unwrap() > 0.0);
        assert_eq!(j.get("arity").as_u64(), Some(2));
        // missing coordinator is a 404, not a hang
        let nf = client.get("/coordinators/app-99/health").unwrap();
        assert_eq!(nf.status, 404);

        // a killed VM shows up as unreachable with bounded rtt
        let app = AppId::parse(&id).unwrap();
        svc.kill_vm(app).unwrap();
        let h = client.get(&format!("/coordinators/{id}/health")).unwrap();
        let j = h.json().unwrap();
        assert_eq!(j.get("healthy").as_bool(), Some(false));
        assert_eq!(j.get("unreachable").as_arr().unwrap().len(), 1);
        let rtt = j.get("rtt_ms").as_f64().unwrap();
        let budget = j.get("budget_ms").as_f64().unwrap();
        assert!(
            rtt < budget * 4.0 + 500.0,
            "detection rtt {rtt}ms must be budget-bounded (budget {budget}ms)"
        );
    }
}

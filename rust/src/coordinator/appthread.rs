//! The application actor runtime (real mode).
//!
//! In the paper every process of an application runs inside its own VM
//! under a DMTCP daemon.  v1 of real mode hosted each
//! [`DistributedApp`] on one dedicated OS thread; thread count then
//! capped realistic deployments at a few hundred apps.  This module is
//! the actor/command-port rework: each app is an **actor** owning its
//! app instance, delta [`Tracker`], and pause/broken flags, receiving
//! typed [`Cmd`]s over a bounded mailbox and emitting [`AppEvent`]s
//! over one unified stream, multiplexed over a bounded worker pool
//! ([`ActorPool`]) instead of one thread per app.
//!
//! Commands still land exactly at step barriers — a worker drains an
//! actor's mailbox between steps, which is the consistent cut the DMTCP
//! drain protocol would otherwise have to establish (DESIGN.md §1) —
//! and the per-actor mailbox is FIFO, so `Pause` + `Progress` still
//! quiesce at an exact iteration and `ResetDelta` ordered before a
//! checkpoint still re-roots that cut.
//!
//! PJRT-backed apps hold `!Send` XLA handles, so the app is **built on
//! its pinned worker** from a `Send` factory and never crosses threads
//! afterwards (actors are slot-pinned, not work-stolen).
//!
//! [`AppHandle`]'s public API is unchanged from the thread-per-app era;
//! it is now a thin command-port client over the shared mailbox.

use crate::dckpt::delta::{DeltaPolicy, Tracker};
use crate::dckpt::service::{self, CheckpointReport};
use crate::dckpt::DistributedApp;
use crate::storage::ObjectStore;
use anyhow::Result;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, Weak};
use std::time::{Duration, Instant};

/// Factory that constructs the app on its pinned worker.
pub type AppFactory = Box<dyn FnOnce() -> Result<Box<dyn DistributedApp>> + Send>;

/// Data-plane call timeout: checkpoint/restore round-trips may move
/// hundreds of MB, so they get minutes.
const DATA_CALL_TIMEOUT: Duration = Duration::from_secs(120);

/// Control-plane probe timeout: reads that feed the REST surface and
/// the §6.3 monitor (`info` progress, health snapshots) must not hang a
/// worker behind a wedged or busy actor — they degrade instead.
pub const CTRL_PROBE_TIMEOUT: Duration = Duration::from_millis(250);

/// How long [`AppHandle`]'s drop waits for its actor to retire before
/// detaching.  A healthy actor is retired at its worker's next pass
/// (µs–ms); a worker stuck in another actor's multi-minute checkpoint
/// would otherwise block recovery / DELETE right along with it.
const JOIN_GRACE: Duration = Duration::from_millis(250);

/// Bounded mailbox: a caller flooding one app gets backpressure (an
/// error) instead of unbounded queue growth inside the control plane.
const MAILBOX_CAP: usize = 1024;

/// Idle worker park time when no actor has a step due.  Mailbox pushes
/// wake the worker explicitly, so this only bounds staleness of the
/// stop-flag scan.
const IDLE_WAIT: Duration = Duration::from_millis(50);

/// Lock that survives a poisoned mutex: a panicking actor must never
/// brick every other app sharing the registry/mailbox lock (the guarded
/// state stays consistent — commands are popped one at a time and
/// handlers run outside the lock).
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Control commands accepted between steps.
pub enum Cmd {
    /// Write a checkpoint (sequence `seq`) into the store.
    /// `allow_delta` lets the dirty-chunk engine emit a delta image
    /// when the previous cut's digests make one worthwhile; either way
    /// the actor's tracker is re-based on this cut.
    Checkpoint {
        seq: u64,
        with_overhead: bool,
        allow_delta: bool,
        reply: SyncSender<Result<CheckpointReport>>,
    },
    /// Forget the delta tracker's digests (the base checkpoint was
    /// deleted): the next cut re-roots the chain with a full image.
    ResetDelta,
    /// Restore from `seq` (None = latest).
    Restore {
        seq: Option<u64>,
        reply: SyncSender<Result<u64>>,
    },
    /// Per-process health snapshot (§6.3 hook results).
    Health { reply: SyncSender<Vec<bool>> },
    /// Progress: (iteration, metric).
    Progress { reply: SyncSender<(u64, f64)> },
    /// Fault injection: kill process `i`.
    Kill { proc: usize },
    /// Fault injection: wedge the actor — it stops servicing commands
    /// entirely (the real-mode analog of a VM whose guest froze: the
    /// app may or may not be fine, but nobody can tell).  Unlike the
    /// thread-per-app era this no longer burns an OS thread: the actor
    /// silently drops every command (replies are never sent, so callers
    /// give up at their own timeout) until its handle is dropped.
    Wedge,
    /// Pause stepping (oversubscription: low-priority jobs swap out).
    Pause,
    /// Resume stepping.
    Resume,
    /// Stop the actor.
    Stop,
}

/// One event on the unified actor event stream.
#[derive(Debug, Clone)]
pub struct AppEvent {
    pub app: String,
    pub kind: AppEventKind,
}

#[derive(Debug, Clone)]
pub enum AppEventKind {
    /// The factory produced the app on its pinned worker.
    Constructed,
    /// The factory failed (or panicked); the actor serves error
    /// sentinels until stopped.
    ConstructFailed(String),
    /// A step returned an error or panicked; the actor stops stepping
    /// but keeps serving its command port.
    StepFailed(String),
    /// A command handler panicked; the caller's reply channel is torn
    /// (it sees a prompt error, not a 120 s timeout).
    CommandPanicked(String),
    CheckpointTaken {
        seq: u64,
        bytes: u64,
        kind: &'static str,
    },
    Restored { seq: u64 },
    Wedged,
    Stopped,
    /// The oversubscription scheduler swapped the app out: checkpointed
    /// at `seq`, actor slot released, image chain parked cold.  Emitted
    /// by the scheduler (not the actor) via [`ActorPool::emit`].
    SwappedOut { seq: u64 },
    /// The scheduler swapped the app back in from its parked cut.
    SwappedIn { seq: u64 },
}

/// Per-subscriber buffer on the event stream.  A subscriber that falls
/// this far behind starts losing events (newest dropped) rather than
/// growing an unbounded queue inside the worker's emit path.
const EVENT_SUB_CAP: usize = MAILBOX_CAP;

/// Fan-out hub for [`AppEvent`]s: one stream carries every actor's
/// lifecycle, so observers subscribe once instead of tapping N apps.
pub struct EventHub {
    subs: Mutex<Vec<SyncSender<AppEvent>>>,
}

impl EventHub {
    fn new() -> EventHub {
        EventHub { subs: Mutex::new(Vec::new()) }
    }

    pub fn subscribe(&self) -> Receiver<AppEvent> {
        let (tx, rx) = sync_channel(EVENT_SUB_CAP);
        lock_unpoisoned(&self.subs).push(tx);
        rx
    }

    fn emit(&self, app: &str, kind: AppEventKind) {
        let mut subs = lock_unpoisoned(&self.subs);
        if subs.is_empty() {
            return;
        }
        let ev = AppEvent { app: app.to_string(), kind };
        // dropped receivers unsubscribe implicitly; a full buffer sheds
        // this event for that subscriber (events are observability, the
        // emitting worker must never block on a slow observer)
        subs.retain(|s| match s.try_send(ev.clone()) {
            Ok(()) => true,
            Err(TrySendError::Full(_)) => {
                log::debug!("{app}: event subscriber lagging; event dropped");
                true
            }
            Err(TrySendError::Disconnected(_)) => false,
        });
    }
}

/// State shared between an [`AppHandle`] and the worker running the
/// actor.  The mailbox is the command port; `stop` is the out-of-band
/// kill switch (honored even by a wedged actor — dropping the handle
/// must always reclaim the slot); `alive` flips false when the worker
/// retires the actor.
struct ActorShared {
    name: String,
    mailbox: Mutex<VecDeque<Cmd>>,
    /// Mirror of the mailbox length for lock-free gauge reads.
    depth: AtomicUsize,
    stop: AtomicBool,
    alive: AtomicBool,
    wake: SyncSender<WorkerMsg>,
}

/// Messages on a worker's inbox (distinct from per-actor mailboxes):
/// actor placement, wake-ups after mailbox pushes, and pool shutdown.
enum WorkerMsg {
    Spawn {
        shared: Arc<ActorShared>,
        factory: AppFactory,
        store: Arc<dyn ObjectStore>,
        step_interval: Duration,
        delta: DeltaPolicy,
    },
    Wake,
    Shutdown,
}

/// What a worker keeps per actor.  Lives only on the pinned worker
/// thread — `app` may hold `!Send` handles.
struct ActorRun {
    shared: Arc<ActorShared>,
    store: Arc<dyn ObjectStore>,
    step_interval: Duration,
    next_step: Instant,
    paused: bool,
    broken: bool, // a proc died / a handler panicked; stop stepping, keep serving
    wedged: bool,
    state: ActorState,
}

enum ActorState {
    Live {
        app: Box<dyn DistributedApp>,
        tracker: Tracker,
        policy: DeltaPolicy,
    },
    /// Construction failed: serve error sentinels (never "healthy").
    Failed,
}

impl ActorRun {
    fn steppable(&self) -> bool {
        !self.paused
            && !self.broken
            && !self.wedged
            && matches!(self.state, ActorState::Live { .. })
    }
}

/// Point-in-time saturation gauges for one [`ActorPool`] — the numbers
/// `GET /coordinators/:id` surfaces so mailbox pressure is observable
/// before it becomes a timeout.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    pub workers: usize,
    pub actors: usize,
    /// Total commands queued across every live mailbox.
    pub mailbox_depth: usize,
    /// Deepest single mailbox.
    pub mailbox_max: usize,
}

/// Bounded worker pool multiplexing many app actors over few OS
/// threads.  Placement is least-loaded at spawn time and sticky for the
/// actor's lifetime (apps may hold `!Send` state).
pub struct ActorPool {
    inboxes: Vec<SyncSender<WorkerMsg>>,
    loads: Vec<Arc<AtomicUsize>>,
    registry: Mutex<Vec<Weak<ActorShared>>>,
    hub: Arc<EventHub>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl ActorPool {
    pub fn new(workers: usize) -> ActorPool {
        let workers = workers.max(1);
        let hub = Arc::new(EventHub::new());
        let mut inboxes = Vec::with_capacity(workers);
        let mut loads = Vec::with_capacity(workers);
        let mut joins = Vec::with_capacity(workers);
        for i in 0..workers {
            // Spawn/Shutdown block when full (true backpressure on actor
            // placement); Wake is lossy try_send, so a burst of command
            // pushes can never wedge a caller on a busy worker's inbox.
            let (tx, rx) = sync_channel(MAILBOX_CAP);
            let load = Arc::new(AtomicUsize::new(0));
            let wload = load.clone();
            let whub = hub.clone();
            let join = std::thread::Builder::new()
                .name(format!("cacs-actor-{i}"))
                .spawn(move || worker_loop(rx, wload, whub))
                // cacs-lint: allow(panic-path) — pool construction runs before any actor exists; a failed worker-thread spawn (OS thread limit) is unrecoverable at this layer
                .expect("spawn actor worker");
            inboxes.push(tx);
            loads.push(load);
            joins.push(join);
        }
        ActorPool {
            inboxes,
            loads,
            registry: Mutex::new(Vec::new()),
            hub,
            workers: Mutex::new(joins),
        }
    }

    /// Place a new actor on the least-loaded worker and hand back its
    /// command-port client.  The factory runs *on the worker* (PJRT
    /// handles are `!Send`), so construction failures surface through
    /// the handle's calls — exactly like the thread-per-app era.
    pub fn spawn(
        &self,
        app_name: &str,
        factory: AppFactory,
        store: Arc<dyn ObjectStore>,
        step_interval: Duration,
        delta: DeltaPolicy,
    ) -> AppHandle {
        let slot = self
            .loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .unwrap_or(0);
        self.loads[slot].fetch_add(1, Ordering::Relaxed);
        let shared = Arc::new(ActorShared {
            name: app_name.to_string(),
            mailbox: Mutex::new(VecDeque::new()),
            depth: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            alive: AtomicBool::new(true),
            wake: self.inboxes[slot].clone(),
        });
        {
            let mut reg = lock_unpoisoned(&self.registry);
            reg.retain(|w| w.strong_count() > 0);
            reg.push(Arc::downgrade(&shared));
        }
        let msg = WorkerMsg::Spawn {
            shared: shared.clone(),
            factory,
            store,
            step_interval,
            delta,
        };
        if self.inboxes[slot].send(msg).is_err() {
            // worker inbox gone (pool shutting down): the actor never
            // starts; mark it retired so callers fail fast
            shared.alive.store(false, Ordering::SeqCst);
            self.loads[slot].fetch_sub(1, Ordering::Relaxed);
        }
        AppHandle { shared, app_name: app_name.to_string() }
    }

    /// Subscribe to the unified event stream (all actors on this pool).
    pub fn subscribe(&self) -> Receiver<AppEvent> {
        self.hub.subscribe()
    }

    /// Publish a control-plane event on the unified stream.  Actors
    /// emit their own lifecycle; this is for decisions made *about* an
    /// app from outside its actor (the oversubscription scheduler's
    /// swap-out/swap-in), so observers see one ordered feed.
    pub(crate) fn emit(&self, app: &str, kind: AppEventKind) {
        self.hub.emit(app, kind);
    }

    pub fn stats(&self) -> PoolStats {
        let mut stats = PoolStats { workers: self.inboxes.len(), ..PoolStats::default() };
        let mut reg = lock_unpoisoned(&self.registry);
        reg.retain(|w| match w.upgrade() {
            Some(shared) => {
                if shared.alive.load(Ordering::SeqCst) {
                    let d = shared.depth.load(Ordering::Relaxed);
                    stats.actors += 1;
                    stats.mailbox_depth += d;
                    stats.mailbox_max = stats.mailbox_max.max(d);
                }
                true
            }
            None => false,
        });
        stats
    }
}

impl Drop for ActorPool {
    fn drop(&mut self) {
        for tx in &self.inboxes {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
        let mut joins = lock_unpoisoned(&self.workers);
        for j in joins.drain(..) {
            // bounded join, same rationale as AppHandle::drop — a
            // worker mid-checkpoint must not hang teardown
            let deadline = Instant::now() + Duration::from_millis(500);
            while !j.is_finished() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(2));
            }
            if j.is_finished() {
                let _ = j.join();
            } else {
                log::warn!("actor worker did not stop in time; detaching");
            }
        }
    }
}

/// The process-wide default pool, used by [`AppHandle::spawn`] /
/// [`AppHandle::spawn_with`] (callers that manage their own pool —
/// the service — use [`ActorPool::spawn`] directly).
fn default_pool() -> &'static ActorPool {
    static POOL: OnceLock<ActorPool> = OnceLock::new();
    POOL.get_or_init(|| ActorPool::new(default_workers()))
}

/// Worker count when the caller didn't choose one.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8)
}

/// Handle to a running application actor: a thin command-port client.
pub struct AppHandle {
    shared: Arc<ActorShared>,
    pub app_name: String,
}

impl AppHandle {
    /// Spawn an actor on the default pool with the default
    /// [`DeltaPolicy`].  `step_interval` throttles stepping (zero = run
    /// hot); `store` is where checkpoint images go.
    pub fn spawn(
        app_name: &str,
        factory: AppFactory,
        store: Arc<dyn ObjectStore>,
        step_interval: Duration,
    ) -> AppHandle {
        AppHandle::spawn_with(app_name, factory, store, step_interval, DeltaPolicy::default())
    }

    /// [`spawn`](AppHandle::spawn) with an explicit delta policy (the
    /// service threads `ServiceConfig::delta` through here).
    pub fn spawn_with(
        app_name: &str,
        factory: AppFactory,
        store: Arc<dyn ObjectStore>,
        step_interval: Duration,
        delta: DeltaPolicy,
    ) -> AppHandle {
        default_pool().spawn(app_name, factory, store, step_interval, delta)
    }

    /// Commands queued on this actor's mailbox right now.
    pub fn mailbox_depth(&self) -> usize {
        self.shared.depth.load(Ordering::Relaxed)
    }

    /// Push a command onto the bounded mailbox and wake the worker.
    fn send(&self, cmd: Cmd) -> Result<()> {
        anyhow::ensure!(self.shared.alive.load(Ordering::SeqCst), "app actor gone");
        {
            let mut mb = lock_unpoisoned(&self.shared.mailbox);
            anyhow::ensure!(mb.len() < MAILBOX_CAP, "app mailbox full ({MAILBOX_CAP})");
            mb.push_back(cmd);
            self.shared.depth.store(mb.len(), Ordering::Relaxed);
        }
        // lossy wake: a full inbox means the worker already has wake-ups
        // queued (it drains the mailbox on the next pass; IDLE_WAIT
        // bounds staleness even if every wake is shed)
        let _ = self.shared.wake.try_send(WorkerMsg::Wake);
        Ok(())
    }

    /// Fire-and-forget command: dropped (with a log line) instead of
    /// erroring when the actor is gone or the mailbox is full.
    fn send_lossy(&self, cmd: Cmd) {
        if let Err(e) = self.send(cmd) {
            log::debug!("{}: dropped command: {e}", self.app_name);
        }
    }

    fn call_within<T, F: FnOnce(SyncSender<T>) -> Cmd>(
        &self,
        timeout: Duration,
        make: F,
    ) -> Result<T> {
        // a reply port carries exactly one message, so capacity 1 makes
        // the handler's send non-blocking while keeping the port bounded
        let (tx, rx) = sync_channel(1);
        self.send(make(tx))?;
        // Disconnected (reply sender dropped: handler panicked, actor
        // wedged/retired) surfaces here as a prompt error rather than
        // waiting out the full timeout
        rx.recv_timeout(timeout)
            .map_err(|_| anyhow::anyhow!("app actor did not answer within {timeout:?}"))
    }

    fn call<T, F: FnOnce(SyncSender<T>) -> Cmd>(&self, make: F) -> Result<T> {
        self.call_within(DATA_CALL_TIMEOUT, make)
    }

    /// Full-image checkpoint (the delta tracker is still re-based on
    /// this cut, so a later delta cut can chain to it).
    pub fn checkpoint(&self, seq: u64, with_overhead: bool) -> Result<CheckpointReport> {
        self.call(|reply| Cmd::Checkpoint { seq, with_overhead, allow_delta: false, reply })?
    }

    /// Policy-driven checkpoint: emits a dirty-chunk delta image when
    /// the engine's digests make one worthwhile, a full image otherwise
    /// (see [`crate::dckpt::service::checkpoint_tracked`]).
    pub fn checkpoint_auto(&self, seq: u64, with_overhead: bool) -> Result<CheckpointReport> {
        self.call(|reply| Cmd::Checkpoint { seq, with_overhead, allow_delta: true, reply })?
    }

    /// Drop the delta tracker's digests; the next cut is a full image.
    /// Fire-and-forget (used when the tracked base checkpoint is
    /// deleted out from under the chain).
    pub fn reset_delta(&self) {
        self.send_lossy(Cmd::ResetDelta);
    }

    pub fn restore(&self, seq: Option<u64>) -> Result<u64> {
        self.call(|reply| Cmd::Restore { seq, reply })?
    }

    pub fn health(&self) -> Result<Vec<bool>> {
        self.call(|reply| Cmd::Health { reply })
    }

    /// Non-blocking health probe (§6.3 leaf hook): the per-proc flags,
    /// or `None` if the actor did not answer within `timeout` — the
    /// monitor treats that as the procs being unreachable.  A late
    /// reply lands on a dropped channel and is discarded harmlessly.
    pub fn try_health(&self, timeout: Duration) -> Option<Vec<bool>> {
        self.call_within(timeout, |reply| Cmd::Health { reply }).ok()
    }

    pub fn progress(&self) -> Result<(u64, f64)> {
        self.call(|reply| Cmd::Progress { reply })
    }

    /// Non-blocking progress probe for control-plane reads (`GET
    /// /coordinators/:id` degrades to the cached record on `None`
    /// instead of hanging the REST worker for the data-plane 120 s).
    pub fn try_progress(&self, timeout: Duration) -> Option<(u64, f64)> {
        self.call_within(timeout, |reply| Cmd::Progress { reply }).ok()
    }

    pub fn kill_proc(&self, proc: usize) {
        self.send_lossy(Cmd::Kill { proc });
    }

    /// Fault injection: wedge the actor (it stops answering
    /// everything).  See [`Cmd::Wedge`].
    pub fn wedge(&self) {
        self.send_lossy(Cmd::Wedge);
    }

    pub fn pause(&self) {
        self.send_lossy(Cmd::Pause);
    }

    pub fn resume(&self) {
        self.send_lossy(Cmd::Resume);
    }

    /// Quiesce stepping at the next step barrier and return the frozen
    /// (iteration, metric).  Pause and the progress round-trip share
    /// the FIFO mailbox, so when this returns the app is stopped
    /// *exactly* at the returned iteration — the consistent cut the
    /// migration orchestrator checkpoints from (commands queued behind
    /// this, e.g. the checkpoint itself, see the same cut).
    pub fn quiesce(&self) -> Result<(u64, f64)> {
        self.send(Cmd::Pause)?;
        self.call(|reply| Cmd::Progress { reply })
    }

    /// Retire the actor and free its worker slot *now*, without
    /// consuming the handle.  `pause` keeps the worker pinned (the slot
    /// stays occupied); swap-out must actually release the resource, so
    /// the scheduler calls this after the victim's checkpoint lands.
    /// Uses the out-of-band stop flag (honored even by a wedged actor)
    /// and waits up to the drop grace period; returns whether the actor
    /// was observed retired.  Every later command on this handle fails
    /// with "app actor gone"; swap-in re-acquires a slot by spawning a
    /// fresh actor from the app's factory.
    pub fn release_slot(&self) -> bool {
        self.shared.stop.store(true, Ordering::SeqCst);
        let _ = self.shared.wake.try_send(WorkerMsg::Wake);
        let deadline = Instant::now() + JOIN_GRACE;
        while self.shared.alive.load(Ordering::SeqCst) && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        !self.shared.alive.load(Ordering::SeqCst)
    }
}

impl Drop for AppHandle {
    fn drop(&mut self) {
        // out-of-band stop: honored even when the actor is wedged (its
        // mailbox is a black hole) — the worker retires it at its next
        // pass and the slot is reclaimed, unlike the thread-per-app era
        // where a wedged host thread leaked until process exit
        self.shared.stop.store(true, Ordering::SeqCst);
        let _ = self.shared.wake.send(WorkerMsg::Wake);
        // Bounded wait: the worker may be deep inside another actor's
        // checkpoint (minutes).  Recovery and DELETE must not block on
        // that, so after the grace period the actor is left to be
        // retired whenever the worker next passes it.  Callers that
        // write to the store after dropping a handle already tolerate a
        // late writer: the checkpoint path re-checks its record and
        // deletes its own images when the coordinator is gone.
        let deadline = Instant::now() + JOIN_GRACE;
        while self.shared.alive.load(Ordering::SeqCst) && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        if self.shared.alive.load(Ordering::SeqCst) {
            log::warn!(
                "{}: actor not retired within {JOIN_GRACE:?}; detaching",
                self.app_name
            );
        }
    }
}

/// One pool worker: owns a set of pinned actors, waits on its inbox
/// with a deadline derived from the earliest due step, and services
/// every actor per pass (drain mailbox at the step barrier, then step).
fn worker_loop(rx: Receiver<WorkerMsg>, load: Arc<AtomicUsize>, hub: Arc<EventHub>) {
    let mut runs: Vec<ActorRun> = Vec::new();
    loop {
        // how long may we park?  zero when any actor has queued
        // commands, a pending stop, or a step already due
        let now = Instant::now();
        let mut wait = IDLE_WAIT;
        let mut due = false;
        for r in &runs {
            if r.shared.stop.load(Ordering::SeqCst) || r.shared.depth.load(Ordering::Relaxed) > 0
            {
                due = true;
                break;
            }
            if r.steppable() {
                let left = r.next_step.saturating_duration_since(now);
                if left.is_zero() {
                    due = true;
                    break;
                }
                wait = wait.min(left);
            }
        }

        let first = if due {
            match rx.try_recv() {
                Ok(m) => Some(m),
                Err(_) => None,
            }
        } else {
            match rx.recv_timeout(wait) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => {
                    // every inbox sender (pool + all handles) is gone:
                    // nothing can ever reach these actors again
                    for run in runs.drain(..) {
                        retire(run, &hub, &load);
                    }
                    return;
                }
            }
        };
        // drain the inbox: coalesce wake-ups, accept placements
        let mut msg = first;
        while let Some(m) = msg {
            match m {
                WorkerMsg::Spawn { shared, factory, store, step_interval, delta } => {
                    runs.push(construct_actor(shared, factory, store, step_interval, delta, &hub));
                }
                WorkerMsg::Wake => {}
                WorkerMsg::Shutdown => {
                    for run in runs.drain(..) {
                        retire(run, &hub, &load);
                    }
                    return;
                }
            }
            msg = rx.try_recv().ok();
        }

        // service every actor: stop flag, mailbox drain, one step
        let mut i = 0;
        while i < runs.len() {
            if runs[i].shared.stop.load(Ordering::SeqCst) {
                let run = runs.swap_remove(i);
                retire(run, &hub, &load);
                continue;
            }
            if service_actor(&mut runs[i], &hub) {
                i += 1;
            } else {
                let run = runs.swap_remove(i);
                retire(run, &hub, &load);
            }
        }
    }
}

/// Run the factory on the pinned worker (§ PJRT `!Send` handles).
/// Failures and panics produce a [`ActorState::Failed`] actor that
/// serves error sentinels — never "healthy" — until stopped.
fn construct_actor(
    shared: Arc<ActorShared>,
    factory: AppFactory,
    store: Arc<dyn ObjectStore>,
    step_interval: Duration,
    delta: DeltaPolicy,
    hub: &EventHub,
) -> ActorRun {
    let name = shared.name.clone();
    let state = match catch_unwind(AssertUnwindSafe(factory)) {
        Ok(Ok(app)) => {
            hub.emit(&name, AppEventKind::Constructed);
            ActorState::Live {
                app,
                tracker: Tracker::new(delta.chunk_size),
                policy: delta,
            }
        }
        Ok(Err(e)) => {
            log::error!("{name}: app construction failed: {e}");
            hub.emit(&name, AppEventKind::ConstructFailed(e.to_string()));
            ActorState::Failed
        }
        Err(_) => {
            log::error!("{name}: app construction panicked");
            hub.emit(&name, AppEventKind::ConstructFailed("factory panicked".into()));
            ActorState::Failed
        }
    };
    ActorRun {
        shared,
        store,
        step_interval,
        next_step: Instant::now(),
        paused: false,
        broken: false,
        wedged: false,
        state,
    }
}

fn retire(run: ActorRun, hub: &EventHub, load: &AtomicUsize) {
    run.shared.alive.store(false, Ordering::SeqCst);
    // commands queued behind the stop never get replies: drop them so
    // blocked callers see Disconnected now instead of a full timeout
    lock_unpoisoned(&run.shared.mailbox).clear();
    run.shared.depth.store(0, Ordering::Relaxed);
    load.fetch_sub(1, Ordering::Relaxed);
    hub.emit(&run.shared.name, AppEventKind::Stopped);
}

/// One service pass over an actor: drain its mailbox (each command
/// lands at a step barrier), then advance at most one throttled step.
/// Returns false when the actor asked to stop.
fn service_actor(run: &mut ActorRun, hub: &EventHub) -> bool {
    loop {
        let cmd = {
            let mut mb = lock_unpoisoned(&run.shared.mailbox);
            let cmd = mb.pop_front();
            run.shared.depth.store(mb.len(), Ordering::Relaxed);
            cmd
        };
        let Some(cmd) = cmd else { break };
        if run.wedged {
            // black hole: drop the command, never reply (callers time
            // out at their own timeout, exactly like a frozen guest)
            continue;
        }
        match catch_unwind(AssertUnwindSafe(|| dispatch(run, cmd, hub))) {
            Ok(Flow::Continue) => {}
            Ok(Flow::Retire) => return false,
            Err(_) => {
                // the handler panicked (e.g. a serialize hook): the
                // reply sender died with it, so the caller gets a
                // prompt error; the app may be mid-mutation, so stop
                // stepping it — and the worker (and every other actor
                // on it) lives on
                run.broken = true;
                let name = run.shared.name.clone();
                log::error!("{name}: command handler panicked; app marked broken");
                hub.emit(&name, AppEventKind::CommandPanicked("command handler panicked".into()));
            }
        }
    }

    if run.steppable() && Instant::now() >= run.next_step {
        if let ActorState::Live { app, .. } = &mut run.state {
            match catch_unwind(AssertUnwindSafe(|| app.step())) {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    let name = &run.shared.name;
                    log::warn!("{name}: step failed: {e}");
                    hub.emit(name, AppEventKind::StepFailed(e.to_string()));
                    run.broken = true;
                }
                Err(_) => {
                    let name = &run.shared.name;
                    log::error!("{name}: step panicked");
                    hub.emit(name, AppEventKind::StepFailed("step panicked".into()));
                    run.broken = true;
                }
            }
            // the deadline is held across commands: a probe must not
            // cut the throttle short (frequent REST polling would
            // otherwise step the app at the poll rate)
            run.next_step = Instant::now() + run.step_interval;
        }
    }
    true
}

enum Flow {
    Continue,
    Retire,
}

fn dispatch(run: &mut ActorRun, cmd: Cmd, hub: &EventHub) -> Flow {
    let name = run.shared.name.clone();
    let ActorState::Live { app, tracker, policy } = &mut run.state else {
        // construct-failed sentinels
        match cmd {
            Cmd::Stop => return Flow::Retire,
            Cmd::Checkpoint { reply, .. } => {
                let _ = reply.send(Err(anyhow::anyhow!("app failed to construct")));
            }
            Cmd::Restore { reply, .. } => {
                let _ = reply.send(Err(anyhow::anyhow!("app failed to construct")));
            }
            Cmd::Health { reply } => {
                // no app was constructed, so there are no per-proc
                // flags.  The empty reply is NOT "all healthy": the
                // service pads it to n_vms × false and the monitor's
                // leaf hooks read the missing flags as unreachable, so
                // a construct-failed app enters recovery instead of
                // sailing under the monitor's radar.
                let _ = reply.send(vec![]);
            }
            Cmd::Progress { reply } => {
                let _ = reply.send((0, f64::NAN));
            }
            _ => {}
        }
        return Flow::Continue;
    };
    match cmd {
        Cmd::Stop => return Flow::Retire,
        Cmd::Pause => run.paused = true,
        Cmd::Resume => run.paused = false,
        Cmd::Kill { proc } => {
            app.kill_proc(proc);
            run.broken = true;
        }
        Cmd::Wedge => {
            log::warn!("{name}: actor wedged by fault injection");
            run.wedged = true;
            hub.emit(&name, AppEventKind::Wedged);
        }
        Cmd::Health { reply } => {
            let h = (0..app.nprocs()).map(|i| app.proc_healthy(i)).collect();
            let _ = reply.send(h);
        }
        Cmd::Progress { reply } => {
            let _ = reply.send((app.iteration(), app.metric()));
        }
        Cmd::Checkpoint { seq, with_overhead, allow_delta, reply } => {
            let r = service::checkpoint_tracked(
                app.as_ref(),
                run.store.as_ref(),
                &name,
                seq,
                with_overhead,
                allow_delta,
                tracker,
                policy,
            );
            if let Ok(report) = &r {
                hub.emit(
                    &name,
                    AppEventKind::CheckpointTaken {
                        seq: report.seq,
                        bytes: report.total_bytes(),
                        kind: report.kind(),
                    },
                );
            }
            let _ = reply.send(r);
        }
        Cmd::ResetDelta => tracker.reset(),
        Cmd::Restore { seq, reply } => {
            let r = service::restore(app.as_mut(), run.store.as_ref(), &name, seq);
            if let Ok(seq) = &r {
                run.broken = false; // revived
                // the live state no longer matches the digests of the
                // last cut — the next checkpoint re-roots the chain
                tracker.reset();
                hub.emit(&name, AppEventKind::Restored { seq: *seq });
            }
            let _ = reply.send(r);
        }
    }
    Flow::Continue
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dckpt::CounterApp;
    use crate::storage::mem::MemStore;

    fn spawn_counter(n: usize) -> (AppHandle, Arc<MemStore>) {
        let store = Arc::new(MemStore::new());
        let s2: Arc<dyn ObjectStore> = store.clone();
        let h = AppHandle::spawn(
            "app-t",
            Box::new(move || Ok(Box::new(CounterApp::new(n, 16)) as Box<dyn DistributedApp>)),
            s2,
            Duration::from_millis(1),
        );
        (h, store)
    }

    #[test]
    fn app_progresses() {
        let (h, _store) = spawn_counter(2);
        std::thread::sleep(Duration::from_millis(50));
        let (it1, _) = h.progress().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let (it2, _) = h.progress().unwrap();
        assert!(it2 > it1, "iterations {it1} -> {it2}");
    }

    #[test]
    fn checkpoint_restore_through_thread() {
        let (h, store) = spawn_counter(3);
        std::thread::sleep(Duration::from_millis(30));
        let report = h.checkpoint(1, false).unwrap();
        assert_eq!(report.image_bytes.len(), 3);
        assert_eq!(store.list("app-t/").unwrap().len(), 3);
        let (it_at_ckpt, _) = h.progress().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let seq = h.restore(None).unwrap();
        assert_eq!(seq, 1);
        let (it_after, _) = h.progress().unwrap();
        // restored close to the checkpoint iteration (a few steps may
        // have run between restore and query)
        assert!(it_after <= it_at_ckpt + 20, "{it_after} vs {it_at_ckpt}");
    }

    #[test]
    fn kill_stops_progress_and_health_reports() {
        let (h, _store) = spawn_counter(2);
        std::thread::sleep(Duration::from_millis(20));
        h.kill_proc(1);
        std::thread::sleep(Duration::from_millis(20));
        let health = h.health().unwrap();
        assert_eq!(health, vec![true, false]);
        let (it1, _) = h.progress().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let (it2, _) = h.progress().unwrap();
        assert_eq!(it1, it2, "broken app must not progress");
    }

    #[test]
    fn restore_revives_killed_proc() {
        let (h, _store) = spawn_counter(2);
        std::thread::sleep(Duration::from_millis(20));
        h.checkpoint(1, false).unwrap();
        h.kill_proc(0);
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(h.health().unwrap(), vec![false, true]);
        h.restore(Some(1)).unwrap();
        assert_eq!(h.health().unwrap(), vec![true, true]);
        let (it1, _) = h.progress().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let (it2, _) = h.progress().unwrap();
        assert!(it2 > it1, "revived app must progress");
    }

    #[test]
    fn pause_resume() {
        let (h, _store) = spawn_counter(1);
        std::thread::sleep(Duration::from_millis(20));
        h.pause();
        std::thread::sleep(Duration::from_millis(20));
        let (it1, _) = h.progress().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let (it2, _) = h.progress().unwrap();
        assert_eq!(it1, it2, "paused app must not progress");
        h.resume();
        std::thread::sleep(Duration::from_millis(50));
        let (it3, _) = h.progress().unwrap();
        assert!(it3 > it2);
    }

    #[test]
    fn quiesce_freezes_at_reported_iteration() {
        let (h, _store) = spawn_counter(1);
        std::thread::sleep(Duration::from_millis(30));
        let (frozen, _) = h.quiesce().unwrap();
        // nothing moves after quiesce returns
        std::thread::sleep(Duration::from_millis(50));
        let (now, _) = h.progress().unwrap();
        assert_eq!(now, frozen, "quiesced app must not step");
        // and a checkpoint taken now is cut exactly there
        let report = h.checkpoint(1, false).unwrap();
        assert_eq!(report.iteration, frozen);
        h.resume();
        std::thread::sleep(Duration::from_millis(50));
        let (later, _) = h.progress().unwrap();
        assert!(later > frozen, "resume restarts stepping");
    }

    #[test]
    fn checkpoint_auto_emits_deltas_and_restore_re_roots() {
        let store = Arc::new(MemStore::new());
        let s2: Arc<dyn ObjectStore> = store.clone();
        let h = AppHandle::spawn_with(
            "app-d",
            Box::new(|| Ok(Box::new(CounterApp::new(1, 4096)) as Box<dyn DistributedApp>)),
            s2,
            Duration::from_millis(1),
            DeltaPolicy { chunk_size: 64, max_dirty_ratio: 0.5, max_chain: 8 },
        );
        std::thread::sleep(Duration::from_millis(20));
        let full = h.checkpoint_auto(1, false).unwrap();
        assert_eq!(full.kind(), "full");
        std::thread::sleep(Duration::from_millis(20));
        let d = h.checkpoint_auto(2, false).unwrap();
        assert_eq!(d.kind(), "delta");
        assert_eq!(d.base_seq, Some(1));
        assert!(
            d.total_bytes() < full.total_bytes() / 4,
            "delta {} vs full {}",
            d.total_bytes(),
            full.total_bytes()
        );
        // a restore resets the tracker: the live state no longer
        // matches the digests, so the next cut re-roots with a full
        h.restore(Some(2)).unwrap();
        let r = h.checkpoint_auto(3, false).unwrap();
        assert_eq!(r.kind(), "full");
        // reset_delta (base deleted under the chain) does the same
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(h.checkpoint_auto(4, false).unwrap().kind(), "delta");
        h.reset_delta();
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(h.checkpoint_auto(5, false).unwrap().kind(), "full");
    }

    #[test]
    fn failed_factory_reports_errors() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let h = AppHandle::spawn("bad", Box::new(|| anyhow::bail!("nope")), store, Duration::ZERO);
        assert!(h.checkpoint(1, false).is_err());
        assert!(h.restore(None).is_err());
        // raw handle view: no flags at all (the service layer is what
        // maps this to "all unreachable" — never to "all healthy")
        assert_eq!(h.health().unwrap(), Vec::<bool>::new());
    }

    #[test]
    fn try_health_answers_fast_and_times_out_on_wedge() {
        let (h, _store) = spawn_counter(2);
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(h.try_health(Duration::from_millis(200)), Some(vec![true, true]));
        assert!(h.try_progress(Duration::from_millis(200)).is_some());
        h.wedge();
        // once the wedge lands, nothing answers — the probe must give
        // up within its own timeout, not the data-plane 120 s
        std::thread::sleep(Duration::from_millis(50));
        let t0 = std::time::Instant::now();
        let r = h.try_health(Duration::from_millis(100));
        assert_eq!(r, None);
        assert!(t0.elapsed() < Duration::from_secs(2), "took {:?}", t0.elapsed());
        let t0 = std::time::Instant::now();
        assert!(h.try_progress(Duration::from_millis(100)).is_none());
        assert!(t0.elapsed() < Duration::from_secs(2));
        // dropping the wedged handle retires the actor via the
        // out-of-band stop flag instead of blocking on the mailbox
        let t0 = std::time::Instant::now();
        drop(h);
        assert!(t0.elapsed() < Duration::from_secs(5), "drop blocked {:?}", t0.elapsed());
    }

    #[test]
    fn pool_multiplexes_many_actors_over_bounded_workers() {
        let pool = ActorPool::new(3);
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let handles: Vec<AppHandle> = (0..24)
            .map(|i| {
                pool.spawn(
                    &format!("app-m{i}"),
                    Box::new(|| {
                        Ok(Box::new(CounterApp::new(1, 16)) as Box<dyn DistributedApp>)
                    }),
                    store.clone(),
                    Duration::from_millis(1),
                    DeltaPolicy::default(),
                )
            })
            .collect();
        let stats = pool.stats();
        assert_eq!(stats.workers, 3);
        assert_eq!(stats.actors, 24);
        std::thread::sleep(Duration::from_millis(60));
        for h in &handles {
            let (it, _) = h.progress().unwrap();
            assert!(it > 0, "{}: never stepped", h.app_name);
        }
        drop(handles);
        let t0 = Instant::now();
        wait_for(|| pool.stats().actors == 0);
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn panicking_actor_does_not_kill_neighbors() {
        struct PanicOnSerialize(CounterApp);
        impl DistributedApp for PanicOnSerialize {
            fn nprocs(&self) -> usize {
                self.0.nprocs()
            }
            fn step(&mut self) -> Result<()> {
                self.0.step()
            }
            fn serialize_proc(&self, _i: usize) -> Result<Vec<u8>> {
                panic!("serialize hook exploded")
            }
            fn restore_proc(&mut self, i: usize, payload: &[u8]) -> Result<()> {
                self.0.restore_proc(i, payload)
            }
            fn proc_healthy(&self, i: usize) -> bool {
                self.0.proc_healthy(i)
            }
            fn kill_proc(&mut self, i: usize) {
                self.0.kill_proc(i)
            }
            fn iteration(&self) -> u64 {
                self.0.iteration()
            }
            fn metric(&self) -> f64 {
                self.0.metric()
            }
            fn kind(&self) -> &'static str {
                "panicky"
            }
        }

        // one worker: both actors share the thread the panic happens on
        let pool = ActorPool::new(1);
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let bad = pool.spawn(
            "app-panic",
            Box::new(|| {
                Ok(Box::new(PanicOnSerialize(CounterApp::new(1, 16))) as Box<dyn DistributedApp>)
            }),
            store.clone(),
            Duration::from_millis(1),
            DeltaPolicy::default(),
        );
        let good = pool.spawn(
            "app-good",
            Box::new(|| Ok(Box::new(CounterApp::new(1, 16)) as Box<dyn DistributedApp>)),
            store,
            Duration::from_millis(1),
            DeltaPolicy::default(),
        );
        std::thread::sleep(Duration::from_millis(20));
        // the panic surfaces as a prompt error, not a 120 s hang
        let t0 = Instant::now();
        assert!(bad.checkpoint(1, false).is_err());
        assert!(t0.elapsed() < Duration::from_secs(5));
        // the neighbor on the same worker keeps stepping and answering
        let (it1, _) = good.progress().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let (it2, _) = good.progress().unwrap();
        assert!(it2 > it1, "neighbor stalled after a panic: {it1} -> {it2}");
        // the panicked actor still serves its command port
        assert_eq!(bad.health().unwrap(), vec![true]);
    }

    #[test]
    fn event_stream_reports_lifecycle() {
        let pool = ActorPool::new(2);
        let events = pool.subscribe();
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let h = pool.spawn(
            "app-ev",
            Box::new(|| Ok(Box::new(CounterApp::new(1, 64)) as Box<dyn DistributedApp>)),
            store,
            Duration::from_millis(1),
            DeltaPolicy::default(),
        );
        std::thread::sleep(Duration::from_millis(20));
        h.checkpoint(1, false).unwrap();
        h.restore(Some(1)).unwrap();
        drop(h);
        let mut saw = Vec::new();
        while let Ok(ev) = events.recv_timeout(Duration::from_millis(500)) {
            assert_eq!(ev.app, "app-ev");
            let tag = match ev.kind {
                AppEventKind::Constructed => "constructed",
                AppEventKind::CheckpointTaken { seq, kind, .. } => {
                    assert_eq!((seq, kind), (1, "full"));
                    "checkpoint"
                }
                AppEventKind::Restored { seq } => {
                    assert_eq!(seq, 1);
                    "restored"
                }
                AppEventKind::Stopped => "stopped",
                _ => "other",
            };
            saw.push(tag);
            if tag == "stopped" {
                break;
            }
        }
        assert_eq!(saw, vec!["constructed", "checkpoint", "restored", "stopped"]);
    }

    #[test]
    fn mailbox_depth_gauge_tracks_queued_commands() {
        let (h, _store) = spawn_counter(1);
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(h.health().unwrap().len(), 1); // drained when idle
        assert_eq!(h.mailbox_depth(), 0);
        h.wedge();
        std::thread::sleep(Duration::from_millis(20));
        // a wedged actor blackholes commands as it pops them, but a
        // burst shows up in the gauge before the worker's next pass;
        // at minimum the gauge must not underflow or error
        for _ in 0..5 {
            h.pause();
        }
        assert!(h.mailbox_depth() <= 5);
    }

    #[test]
    fn release_slot_frees_worker_slot_without_dropping_handle() {
        // the pause-semantics fix: pause keeps the slot pinned, so
        // parked jobs used to starve runnable ones.  release_slot frees
        // the slot while the handle (and the app's record) live on.
        let pool = ActorPool::new(2);
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let handles: Vec<AppHandle> = (0..4)
            .map(|i| {
                pool.spawn(
                    &format!("app-r{i}"),
                    Box::new(|| Ok(Box::new(CounterApp::new(1, 16)) as Box<dyn DistributedApp>)),
                    store.clone(),
                    Duration::from_millis(1),
                    DeltaPolicy::default(),
                )
            })
            .collect();
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(pool.stats().actors, 4);
        for h in &handles {
            assert!(h.release_slot(), "{}: actor did not retire", h.app_name);
        }
        wait_for(|| pool.stats().actors == 0);
        // the released handle answers nothing but is still droppable
        assert!(handles[0].progress().is_err());
        // freed slots are re-acquirable: a fresh spawn runs fine
        let h2 = pool.spawn(
            "app-r-again",
            Box::new(|| Ok(Box::new(CounterApp::new(1, 16)) as Box<dyn DistributedApp>)),
            store.clone(),
            Duration::from_millis(1),
            DeltaPolicy::default(),
        );
        std::thread::sleep(Duration::from_millis(40));
        assert!(h2.progress().unwrap().0 > 0);
        drop(handles);
    }

    fn wait_for(f: impl Fn() -> bool) {
        for _ in 0..400 {
            if f() {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        panic!("condition never became true");
    }
}

//! The application host thread (real mode).
//!
//! In the paper every process of an application runs inside its own VM
//! under a DMTCP daemon.  In real mode we host the whole
//! [`DistributedApp`] on one dedicated thread that steps it continuously
//! and services control commands (checkpoint, restore, health, kill)
//! between steps — each command lands exactly at a step barrier, which
//! is the consistent cut the DMTCP drain protocol would otherwise have
//! to establish (DESIGN.md §1).
//!
//! PJRT-backed apps hold `!Send` XLA handles, so the app is **built on
//! the thread** from a `Send` factory and never crosses threads.

use crate::dckpt::delta::{DeltaPolicy, Tracker};
use crate::dckpt::service::{self, CheckpointReport};
use crate::dckpt::DistributedApp;
use crate::storage::ObjectStore;
use anyhow::Result;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Factory that constructs the app on its host thread.
pub type AppFactory = Box<dyn FnOnce() -> Result<Box<dyn DistributedApp>> + Send>;

/// Data-plane call timeout: checkpoint/restore round-trips may move
/// hundreds of MB, so they get minutes.
const DATA_CALL_TIMEOUT: Duration = Duration::from_secs(120);

/// Control-plane probe timeout: reads that feed the REST surface and
/// the §6.3 monitor (`info` progress, health snapshots) must not hang a
/// worker behind a wedged or busy host thread — they degrade instead.
pub const CTRL_PROBE_TIMEOUT: Duration = Duration::from_millis(250);

/// How long [`AppHandle`]'s drop waits for the host thread to exit
/// before detaching it.  A healthy thread answers `Stop` at its next
/// step barrier (µs–ms); a wedged one never would, and recovery /
/// DELETE must not block 120 s (or forever) joining it.
const JOIN_GRACE: Duration = Duration::from_millis(250);

/// Control commands accepted between steps.
pub enum Cmd {
    /// Write a checkpoint (sequence `seq`) into the store.
    /// `allow_delta` lets the dirty-chunk engine emit a delta image
    /// when the previous cut's digests make one worthwhile; either way
    /// the host thread's tracker is re-based on this cut.
    Checkpoint {
        seq: u64,
        with_overhead: bool,
        allow_delta: bool,
        reply: Sender<Result<CheckpointReport>>,
    },
    /// Forget the delta tracker's digests (the base checkpoint was
    /// deleted): the next cut re-roots the chain with a full image.
    ResetDelta,
    /// Restore from `seq` (None = latest).
    Restore {
        seq: Option<u64>,
        reply: Sender<Result<u64>>,
    },
    /// Per-process health snapshot (§6.3 hook results).
    Health { reply: Sender<Vec<bool>> },
    /// Progress: (iteration, metric).
    Progress { reply: Sender<(u64, f64)> },
    /// Fault injection: kill process `i`.
    Kill { proc: usize },
    /// Fault injection: wedge the host thread itself — it stops
    /// servicing commands entirely (the real-mode analog of a VM whose
    /// guest froze: the app may or may not be fine, but nobody can
    /// tell).  Only detaching the thread gets rid of it.
    Wedge,
    /// Pause stepping (oversubscription: low-priority jobs swap out).
    Pause,
    /// Resume stepping.
    Resume,
    /// Stop the thread.
    Stop,
}

/// Handle to a running application thread.
pub struct AppHandle {
    tx: Sender<Cmd>,
    join: Option<std::thread::JoinHandle<()>>,
    pub app_name: String,
}

impl AppHandle {
    /// Spawn the host thread with the default [`DeltaPolicy`].
    /// `step_interval` throttles stepping (zero = run hot); `store` is
    /// where checkpoint images go.
    pub fn spawn(
        app_name: &str,
        factory: AppFactory,
        store: Arc<dyn ObjectStore>,
        step_interval: Duration,
    ) -> AppHandle {
        AppHandle::spawn_with(app_name, factory, store, step_interval, DeltaPolicy::default())
    }

    /// [`spawn`](AppHandle::spawn) with an explicit delta policy (the
    /// service threads `ServiceConfig::delta` through here).
    pub fn spawn_with(
        app_name: &str,
        factory: AppFactory,
        store: Arc<dyn ObjectStore>,
        step_interval: Duration,
        delta: DeltaPolicy,
    ) -> AppHandle {
        let (tx, rx) = channel();
        let name = app_name.to_string();
        let thread_name = format!("cacs-app-{name}");
        let join = std::thread::Builder::new()
            .name(thread_name)
            .spawn(move || host_loop(&name, factory, store, step_interval, delta, rx))
            .expect("spawn app thread");
        AppHandle { tx, join: Some(join), app_name: app_name.to_string() }
    }

    fn call_within<T, F: FnOnce(Sender<T>) -> Cmd>(
        &self,
        timeout: Duration,
        make: F,
    ) -> Result<T> {
        let (tx, rx) = channel();
        self.tx
            .send(make(tx))
            .map_err(|_| anyhow::anyhow!("app thread gone"))?;
        rx.recv_timeout(timeout)
            .map_err(|_| anyhow::anyhow!("app thread did not answer within {timeout:?}"))
    }

    fn call<T, F: FnOnce(Sender<T>) -> Cmd>(&self, make: F) -> Result<T> {
        self.call_within(DATA_CALL_TIMEOUT, make)
    }

    /// Full-image checkpoint (the delta tracker is still re-based on
    /// this cut, so a later delta cut can chain to it).
    pub fn checkpoint(&self, seq: u64, with_overhead: bool) -> Result<CheckpointReport> {
        self.call(|reply| Cmd::Checkpoint { seq, with_overhead, allow_delta: false, reply })?
    }

    /// Policy-driven checkpoint: emits a dirty-chunk delta image when
    /// the engine's digests make one worthwhile, a full image otherwise
    /// (see [`crate::dckpt::service::checkpoint_tracked`]).
    pub fn checkpoint_auto(&self, seq: u64, with_overhead: bool) -> Result<CheckpointReport> {
        self.call(|reply| Cmd::Checkpoint { seq, with_overhead, allow_delta: true, reply })?
    }

    /// Drop the delta tracker's digests; the next cut is a full image.
    /// Fire-and-forget (used when the tracked base checkpoint is
    /// deleted out from under the chain).
    pub fn reset_delta(&self) {
        let _ = self.tx.send(Cmd::ResetDelta);
    }

    pub fn restore(&self, seq: Option<u64>) -> Result<u64> {
        self.call(|reply| Cmd::Restore { seq, reply })?
    }

    pub fn health(&self) -> Result<Vec<bool>> {
        self.call(|reply| Cmd::Health { reply })
    }

    /// Non-blocking health probe (§6.3 leaf hook): the per-proc flags,
    /// or `None` if the host thread did not answer within `timeout` —
    /// the monitor treats that as the procs being unreachable.  A late
    /// reply lands on a dropped channel and is discarded harmlessly.
    pub fn try_health(&self, timeout: Duration) -> Option<Vec<bool>> {
        self.call_within(timeout, |reply| Cmd::Health { reply }).ok()
    }

    pub fn progress(&self) -> Result<(u64, f64)> {
        self.call(|reply| Cmd::Progress { reply })
    }

    /// Non-blocking progress probe for control-plane reads (`GET
    /// /coordinators/:id` degrades to the cached record on `None`
    /// instead of hanging the REST worker for the data-plane 120 s).
    pub fn try_progress(&self, timeout: Duration) -> Option<(u64, f64)> {
        self.call_within(timeout, |reply| Cmd::Progress { reply }).ok()
    }

    pub fn kill_proc(&self, proc: usize) {
        let _ = self.tx.send(Cmd::Kill { proc });
    }

    /// Fault injection: wedge the host thread (it stops answering
    /// everything, including `Stop`).  See [`Cmd::Wedge`].
    pub fn wedge(&self) {
        let _ = self.tx.send(Cmd::Wedge);
    }

    pub fn pause(&self) {
        let _ = self.tx.send(Cmd::Pause);
    }

    pub fn resume(&self) {
        let _ = self.tx.send(Cmd::Resume);
    }

    /// Quiesce stepping at the next step barrier and return the frozen
    /// (iteration, metric).  Pause and the progress round-trip share
    /// the FIFO command queue, so when this returns the app is stopped
    /// *exactly* at the returned iteration — the consistent cut the
    /// migration orchestrator checkpoints from (commands queued behind
    /// this, e.g. the checkpoint itself, see the same cut).
    pub fn quiesce(&self) -> Result<(u64, f64)> {
        let _ = self.tx.send(Cmd::Pause);
        self.call(|reply| Cmd::Progress { reply })
    }
}

impl Drop for AppHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Stop);
        if let Some(j) = self.join.take() {
            // Bounded join: a wedged host thread never answers Stop, and
            // an unbounded join here would wedge recovery (and DELETE)
            // right along with it.  Wait a grace period, then detach —
            // the thread either exits on its own (e.g. once an
            // in-flight checkpoint drains and it sees Stop) or is
            // reaped at process exit.  Callers that write to the store
            // after dropping a handle already tolerate a late writer:
            // the checkpoint path re-checks its record and deletes its
            // own images when the coordinator is gone.
            let deadline = Instant::now() + JOIN_GRACE;
            while !j.is_finished() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(2));
            }
            if j.is_finished() {
                let _ = j.join();
            } else {
                log::warn!(
                    "{}: host thread did not stop within {JOIN_GRACE:?}; detaching",
                    self.app_name
                );
            }
        }
    }
}

/// Everything the host loop mutates while serving commands: the app
/// itself, the pause/broken flags, and the delta tracker whose digests
/// persist across cuts.
struct HostState {
    app: Box<dyn DistributedApp>,
    paused: bool,
    broken: bool, // a proc died; stop stepping, keep serving
    tracker: Tracker,
    policy: DeltaPolicy,
}

/// Shared command handling; returns false when the thread must exit.
fn handle_cmd(cmd: Cmd, st: &mut HostState, app_name: &str, store: &Arc<dyn ObjectStore>) -> bool {
    match cmd {
        Cmd::Stop => return false,
        Cmd::Pause => st.paused = true,
        Cmd::Resume => st.paused = false,
        Cmd::Kill { proc } => {
            st.app.kill_proc(proc);
            st.broken = true;
        }
        Cmd::Wedge => {
            log::warn!("{app_name}: host thread wedged by fault injection");
            loop {
                std::thread::sleep(Duration::from_secs(60));
            }
        }
        Cmd::Health { reply } => {
            let h = (0..st.app.nprocs()).map(|i| st.app.proc_healthy(i)).collect();
            let _ = reply.send(h);
        }
        Cmd::Progress { reply } => {
            let _ = reply.send((st.app.iteration(), st.app.metric()));
        }
        Cmd::Checkpoint { seq, with_overhead, allow_delta, reply } => {
            let r = service::checkpoint_tracked(
                st.app.as_ref(),
                store.as_ref(),
                app_name,
                seq,
                with_overhead,
                allow_delta,
                &mut st.tracker,
                &st.policy,
            );
            let _ = reply.send(r);
        }
        Cmd::ResetDelta => st.tracker.reset(),
        Cmd::Restore { seq, reply } => {
            let r = service::restore(st.app.as_mut(), store.as_ref(), app_name, seq);
            if r.is_ok() {
                st.broken = false; // revived
                // the live state no longer matches the digests of the
                // last cut — the next checkpoint re-roots the chain
                st.tracker.reset();
            }
            let _ = reply.send(r);
        }
    }
    true
}

fn host_loop(
    app_name: &str,
    factory: AppFactory,
    store: Arc<dyn ObjectStore>,
    step_interval: Duration,
    delta: DeltaPolicy,
    rx: Receiver<Cmd>,
) {
    let app: Box<dyn DistributedApp> = match factory() {
        Ok(a) => a,
        Err(e) => {
            log::error!("{app_name}: app construction failed: {e}");
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    Cmd::Stop => return,
                    Cmd::Checkpoint { reply, .. } => {
                        let _ = reply.send(Err(anyhow::anyhow!("app failed to construct")));
                    }
                    Cmd::Restore { reply, .. } => {
                        let _ = reply.send(Err(anyhow::anyhow!("app failed to construct")));
                    }
                    Cmd::Health { reply } => {
                        // no app was constructed, so there are no
                        // per-proc flags.  The empty reply is NOT "all
                        // healthy": the service pads it to n_vms ×
                        // false and the monitor's leaf hooks read the
                        // missing flags as unreachable, so a
                        // construct-failed app enters recovery instead
                        // of sailing under the monitor's radar.
                        let _ = reply.send(vec![]);
                    }
                    Cmd::Progress { reply } => {
                        let _ = reply.send((0, f64::NAN));
                    }
                    _ => {}
                }
            }
            return;
        }
    };

    let tracker = Tracker::new(delta.chunk_size);
    let mut st = HostState { app, paused: false, broken: false, tracker, policy: delta };
    loop {
        // drain pending commands (each lands at a step barrier)
        loop {
            match rx.try_recv() {
                Ok(cmd) => {
                    if !handle_cmd(cmd, &mut st, app_name, &store) {
                        return;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return,
            }
        }

        if st.paused || st.broken {
            // block (bounded) instead of spinning
            if let Ok(cmd) = rx.recv_timeout(Duration::from_millis(50)) {
                if !handle_cmd(cmd, &mut st, app_name, &store) {
                    return;
                }
            }
            continue;
        }

        match st.app.step() {
            Ok(()) => {}
            Err(e) => {
                log::warn!("{app_name}: step failed: {e}");
                st.broken = true;
                continue;
            }
        }
        if !step_interval.is_zero() {
            // throttle by waiting on the command queue instead of a
            // blind sleep: a heavily throttled but healthy app must
            // still answer control probes (health/progress) inside the
            // §6.3 heartbeat budget, not one step_interval late.  The
            // wait holds the full interval deadline across commands —
            // a probe must not cut the throttle short (frequent REST
            // polling would otherwise step the app at the poll rate)
            let next_step = Instant::now() + step_interval;
            loop {
                let left = next_step.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(cmd) => {
                        if !handle_cmd(cmd, &mut st, app_name, &store) {
                            return;
                        }
                        if st.paused || st.broken {
                            break; // the main loop's parked branch takes over
                        }
                    }
                    Err(_) => break, // interval elapsed (or sender gone)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dckpt::CounterApp;
    use crate::storage::mem::MemStore;

    fn spawn_counter(n: usize) -> (AppHandle, Arc<MemStore>) {
        let store = Arc::new(MemStore::new());
        let s2: Arc<dyn ObjectStore> = store.clone();
        let h = AppHandle::spawn(
            "app-t",
            Box::new(move || Ok(Box::new(CounterApp::new(n, 16)) as Box<dyn DistributedApp>)),
            s2,
            Duration::from_millis(1),
        );
        (h, store)
    }

    #[test]
    fn app_progresses() {
        let (h, _store) = spawn_counter(2);
        std::thread::sleep(Duration::from_millis(50));
        let (it1, _) = h.progress().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let (it2, _) = h.progress().unwrap();
        assert!(it2 > it1, "iterations {it1} -> {it2}");
    }

    #[test]
    fn checkpoint_restore_through_thread() {
        let (h, store) = spawn_counter(3);
        std::thread::sleep(Duration::from_millis(30));
        let report = h.checkpoint(1, false).unwrap();
        assert_eq!(report.image_bytes.len(), 3);
        assert_eq!(store.list("app-t/").unwrap().len(), 3);
        let (it_at_ckpt, _) = h.progress().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let seq = h.restore(None).unwrap();
        assert_eq!(seq, 1);
        let (it_after, _) = h.progress().unwrap();
        // restored close to the checkpoint iteration (a few steps may
        // have run between restore and query)
        assert!(it_after <= it_at_ckpt + 20, "{it_after} vs {it_at_ckpt}");
    }

    #[test]
    fn kill_stops_progress_and_health_reports() {
        let (h, _store) = spawn_counter(2);
        std::thread::sleep(Duration::from_millis(20));
        h.kill_proc(1);
        std::thread::sleep(Duration::from_millis(20));
        let health = h.health().unwrap();
        assert_eq!(health, vec![true, false]);
        let (it1, _) = h.progress().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let (it2, _) = h.progress().unwrap();
        assert_eq!(it1, it2, "broken app must not progress");
    }

    #[test]
    fn restore_revives_killed_proc() {
        let (h, _store) = spawn_counter(2);
        std::thread::sleep(Duration::from_millis(20));
        h.checkpoint(1, false).unwrap();
        h.kill_proc(0);
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(h.health().unwrap(), vec![false, true]);
        h.restore(Some(1)).unwrap();
        assert_eq!(h.health().unwrap(), vec![true, true]);
        let (it1, _) = h.progress().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let (it2, _) = h.progress().unwrap();
        assert!(it2 > it1, "revived app must progress");
    }

    #[test]
    fn pause_resume() {
        let (h, _store) = spawn_counter(1);
        std::thread::sleep(Duration::from_millis(20));
        h.pause();
        std::thread::sleep(Duration::from_millis(20));
        let (it1, _) = h.progress().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let (it2, _) = h.progress().unwrap();
        assert_eq!(it1, it2, "paused app must not progress");
        h.resume();
        std::thread::sleep(Duration::from_millis(50));
        let (it3, _) = h.progress().unwrap();
        assert!(it3 > it2);
    }

    #[test]
    fn quiesce_freezes_at_reported_iteration() {
        let (h, _store) = spawn_counter(1);
        std::thread::sleep(Duration::from_millis(30));
        let (frozen, _) = h.quiesce().unwrap();
        // nothing moves after quiesce returns
        std::thread::sleep(Duration::from_millis(50));
        let (now, _) = h.progress().unwrap();
        assert_eq!(now, frozen, "quiesced app must not step");
        // and a checkpoint taken now is cut exactly there
        let report = h.checkpoint(1, false).unwrap();
        assert_eq!(report.iteration, frozen);
        h.resume();
        std::thread::sleep(Duration::from_millis(50));
        let (later, _) = h.progress().unwrap();
        assert!(later > frozen, "resume restarts stepping");
    }

    #[test]
    fn checkpoint_auto_emits_deltas_and_restore_re_roots() {
        let store = Arc::new(MemStore::new());
        let s2: Arc<dyn ObjectStore> = store.clone();
        let h = AppHandle::spawn_with(
            "app-d",
            Box::new(|| Ok(Box::new(CounterApp::new(1, 4096)) as Box<dyn DistributedApp>)),
            s2,
            Duration::from_millis(1),
            DeltaPolicy { chunk_size: 64, max_dirty_ratio: 0.5, max_chain: 8 },
        );
        std::thread::sleep(Duration::from_millis(20));
        let full = h.checkpoint_auto(1, false).unwrap();
        assert_eq!(full.kind(), "full");
        std::thread::sleep(Duration::from_millis(20));
        let d = h.checkpoint_auto(2, false).unwrap();
        assert_eq!(d.kind(), "delta");
        assert_eq!(d.base_seq, Some(1));
        assert!(
            d.total_bytes() < full.total_bytes() / 4,
            "delta {} vs full {}",
            d.total_bytes(),
            full.total_bytes()
        );
        // a restore resets the tracker: the live state no longer
        // matches the digests, so the next cut re-roots with a full
        h.restore(Some(2)).unwrap();
        let r = h.checkpoint_auto(3, false).unwrap();
        assert_eq!(r.kind(), "full");
        // reset_delta (base deleted under the chain) does the same
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(h.checkpoint_auto(4, false).unwrap().kind(), "delta");
        h.reset_delta();
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(h.checkpoint_auto(5, false).unwrap().kind(), "full");
    }

    #[test]
    fn failed_factory_reports_errors() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let h = AppHandle::spawn("bad", Box::new(|| anyhow::bail!("nope")), store, Duration::ZERO);
        assert!(h.checkpoint(1, false).is_err());
        assert!(h.restore(None).is_err());
        // raw handle view: no flags at all (the service layer is what
        // maps this to "all unreachable" — never to "all healthy")
        assert_eq!(h.health().unwrap(), Vec::<bool>::new());
    }

    #[test]
    fn try_health_answers_fast_and_times_out_on_wedge() {
        let (h, _store) = spawn_counter(2);
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(h.try_health(Duration::from_millis(200)), Some(vec![true, true]));
        assert!(h.try_progress(Duration::from_millis(200)).is_some());
        h.wedge();
        // the wedge lands at the next step barrier; after that nothing
        // answers — the probe must give up at its own timeout, not 120 s
        std::thread::sleep(Duration::from_millis(50));
        let t0 = std::time::Instant::now();
        let r = h.try_health(Duration::from_millis(100));
        assert_eq!(r, None);
        assert!(t0.elapsed() < Duration::from_secs(2), "took {:?}", t0.elapsed());
        let t0 = std::time::Instant::now();
        assert!(h.try_progress(Duration::from_millis(100)).is_none());
        assert!(t0.elapsed() < Duration::from_secs(2));
        // dropping the wedged handle detaches instead of joining forever
        let t0 = std::time::Instant::now();
        drop(h);
        assert!(t0.elapsed() < Duration::from_secs(5), "drop blocked {:?}", t0.elapsed());
    }
}

//! Dirty-chunk delta engine for incremental checkpoints.
//!
//! The steady-state cost of periodic checkpointing (§5.2 mode 2) is
//! dominated by image size: every cut used to stream the *full* process
//! state no matter how little changed since the previous cut.  This
//! module turns that O(state) into O(dirty): the writer keeps one
//! 64-bit digest per `chunk_size` slice of each process's serialized
//! state, diffs the fresh payload against the previous cut's digests,
//! and emits a v2 delta image ([`crate::dckpt::image::DeltaTable`])
//! carrying only the dirty chunks.
//!
//! Self-healing: when the dirty ratio exceeds
//! [`DeltaPolicy::max_dirty_ratio`] a full image is written instead
//! (the delta would not pay for itself), and every
//! [`DeltaPolicy::max_chain`] delta cuts a full image is forced so
//! restore never replays an unbounded chain.  A restore (or a deleted
//! base) resets the tracker, so the next cut re-roots the chain with a
//! full image.

use super::image::{ChunkRef, DeltaTable};
use anyhow::{bail, Result};

/// Default diff granularity (one digest per 64 KiB of state).
pub const DEFAULT_CHUNK_SIZE: usize = 64 * 1024;
/// Default dirty-ratio ceiling above which a full image is cheaper.
pub const DEFAULT_MAX_DIRTY_RATIO: f64 = 0.5;
/// Default chain-length bound (a full image is forced after this many
/// consecutive delta cuts).
pub const DEFAULT_MAX_CHAIN: u64 = 8;

/// Knobs of the delta engine (`ServiceConfig::delta` in the real-mode
/// service).
#[derive(Debug, Clone)]
pub struct DeltaPolicy {
    /// Diff granularity in bytes.
    pub chunk_size: usize,
    /// Emit a delta only when `dirty_bytes / payload_len` is at or
    /// under this; otherwise fall back to a full image.
    pub max_dirty_ratio: f64,
    /// Force a full image after this many consecutive delta cuts.
    pub max_chain: u64,
}

impl Default for DeltaPolicy {
    fn default() -> DeltaPolicy {
        DeltaPolicy {
            chunk_size: DEFAULT_CHUNK_SIZE,
            max_dirty_ratio: DEFAULT_MAX_DIRTY_RATIO,
            max_chain: DEFAULT_MAX_CHAIN,
        }
    }
}

/// 64-bit chunk digest (FNV-1a with a final avalanche), seeded with the
/// chunk length so a truncated tail chunk never collides with its
/// longer predecessor.  Speed-of-light is one pass over the bytes —
/// cheap next to the CRC/serialize work the cut already does.
pub fn chunk_digest(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325 ^ (data.len() as u64).wrapping_mul(0x9E3779B97F4A7C15);
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    // avalanche (splitmix64 finalizer) so single-byte differences flip
    // high bits too
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58476d1ce4e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d049bb133111eb);
    h ^ (h >> 31)
}

/// Digest every `chunk_size` slice of `payload` (tail chunk may be
/// short).  An empty payload has no chunks.
pub fn digest_chunks(payload: &[u8], chunk_size: usize) -> Vec<u64> {
    assert!(chunk_size > 0, "chunk_size must be positive");
    payload.chunks(chunk_size).map(chunk_digest).collect()
}

/// Per-process digest state from the previous cut.
#[derive(Debug, Clone)]
pub struct ProcDigests {
    /// Raw payload length the digests describe.
    pub payload_len: u64,
    /// One digest per chunk, in order.
    pub digests: Vec<u64>,
}

/// Chunk indices whose fresh digests differ from `prev` (including
/// every chunk beyond the previous payload's coverage).
pub fn dirty_from_digests(prev: &ProcDigests, fresh: &[u64]) -> Vec<usize> {
    fresh
        .iter()
        .enumerate()
        .filter(|&(i, d)| prev.digests.get(i) != Some(d))
        .map(|(i, _)| i)
        .collect()
}

/// [`dirty_from_digests`] over a raw payload (digests computed here).
pub fn dirty_chunks(prev: &ProcDigests, payload: &[u8], chunk_size: usize) -> Vec<usize> {
    dirty_from_digests(prev, &digest_chunks(payload, chunk_size))
}

/// Build the chunk table for `dirty` indices of `payload`; returns the
/// table plus the delta payload size.
pub fn build_table(
    base_seq: u64,
    base_len: u64,
    payload: &[u8],
    chunk_size: usize,
    dirty: &[usize],
) -> DeltaTable {
    let mut chunks = Vec::with_capacity(dirty.len());
    let mut offset = 0u64;
    for &i in dirty {
        let start = i * chunk_size;
        let len = chunk_size.min(payload.len() - start) as u64;
        chunks.push(ChunkRef { index: i as u64, offset, len });
        offset += len;
    }
    DeltaTable {
        base_seq,
        base_len,
        full_len: payload.len() as u64,
        chunk_size: chunk_size as u64,
        chunks,
    }
}

/// Reconstruct a payload: start from `base`, resize to the table's
/// `full_len`, then overlay every chunk from `delta_payload`.  `out` is
/// a scratch buffer the caller reuses across procs/links.
pub fn apply(
    base: &[u8],
    table: &DeltaTable,
    delta_payload: &[u8],
    out: &mut Vec<u8>,
) -> Result<()> {
    if base.len() as u64 != table.base_len {
        bail!(
            "delta base length mismatch: have {}, table expects {}",
            base.len(),
            table.base_len
        );
    }
    if table.payload_bytes() != delta_payload.len() as u64 {
        bail!(
            "delta payload length mismatch: have {}, chunk table covers {}",
            delta_payload.len(),
            table.payload_bytes()
        );
    }
    let full_len = table.full_len as usize;
    let chunk_size = table.chunk_size as usize;
    if chunk_size == 0 {
        bail!("delta chunk_size must be positive");
    }
    out.clear();
    out.extend_from_slice(&base[..base.len().min(full_len)]);
    out.resize(full_len, 0);
    for c in &table.chunks {
        let dst = (c.index as usize).checked_mul(chunk_size).unwrap_or(usize::MAX);
        let (src, len) = (c.offset as usize, c.len as usize);
        if dst.checked_add(len).map(|e| e > full_len).unwrap_or(true) {
            bail!("delta chunk {} overruns payload ({dst}+{len} > {full_len})", c.index);
        }
        if src + len > delta_payload.len() {
            bail!("delta chunk {} overruns delta payload", c.index);
        }
        if len > chunk_size || (len < chunk_size && dst + len != full_len) {
            bail!("delta chunk {} has inconsistent length {len}", c.index);
        }
        out[dst..dst + len].copy_from_slice(&delta_payload[src..src + len]);
    }
    Ok(())
}

/// Per-application digest tracker, owned by whoever drives consecutive
/// cuts (the real-mode app host thread).  `base_seq` is the sequence of
/// the last successful cut — the base the next delta diffs against.
#[derive(Debug)]
pub struct Tracker {
    /// Diff granularity the digests were computed at.
    pub chunk_size: usize,
    /// Last successful cut, if any (deltas chain to it).
    pub base_seq: Option<u64>,
    /// Consecutive cuts that emitted at least one delta image.
    pub chain_len: u64,
    /// Per-proc digests from the last successful cut.
    pub procs: Vec<ProcDigests>,
}

impl Tracker {
    pub fn new(chunk_size: usize) -> Tracker {
        Tracker { chunk_size, base_seq: None, chain_len: 0, procs: vec![] }
    }

    /// Forget everything: the next cut is a full image that re-roots
    /// the chain.  Called after a restore (the live state no longer
    /// matches the digests) and when the base checkpoint is deleted.
    pub fn reset(&mut self) {
        self.base_seq = None;
        self.chain_len = 0;
        self.procs.clear();
    }

    /// Whether the next cut may emit deltas against `base_seq`.
    pub fn delta_eligible(&self, nprocs: usize, policy: &DeltaPolicy) -> bool {
        self.base_seq.is_some()
            && self.procs.len() == nprocs
            && self.chunk_size == policy.chunk_size
            && self.chain_len < policy.max_chain
    }

    /// Commit a successful cut: the fresh digests become the base for
    /// the next diff.  `any_delta` says whether this cut emitted at
    /// least one delta image (extends the chain) or was entirely full
    /// (re-roots it).
    pub fn commit(&mut self, seq: u64, procs: Vec<ProcDigests>, any_delta: bool) {
        self.procs = procs;
        self.base_seq = Some(seq);
        self.chain_len = if any_delta { self.chain_len + 1 } else { 0 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_differs_on_content_and_length() {
        assert_ne!(chunk_digest(b"aaaa"), chunk_digest(b"aaab"));
        assert_ne!(chunk_digest(b"aaaa"), chunk_digest(b"aaa"));
        assert_eq!(chunk_digest(b"same"), chunk_digest(b"same"));
        // empty chunk digests consistently
        assert_eq!(chunk_digest(b""), chunk_digest(b""));
    }

    #[test]
    fn dirty_chunks_finds_exactly_the_changes() {
        let cs = 8;
        let base: Vec<u8> = (0..64u8).collect();
        let prev = ProcDigests {
            payload_len: base.len() as u64,
            digests: digest_chunks(&base, cs),
        };
        // unchanged payload: nothing dirty
        assert!(dirty_chunks(&prev, &base, cs).is_empty());
        // flip one byte in chunk 3
        let mut dirty = base.clone();
        dirty[3 * 8 + 2] ^= 0xFF;
        assert_eq!(dirty_chunks(&prev, &dirty, cs), vec![3]);
        // grow the payload: the tail chunks are dirty
        let mut grown = base.clone();
        grown.extend_from_slice(&[9u8; 20]);
        let d = dirty_chunks(&prev, &grown, cs);
        assert!(d.contains(&8) && d.contains(&9) && d.contains(&10), "{d:?}");
        assert!(!d.contains(&0));
    }

    #[test]
    fn build_and_apply_roundtrip() {
        let cs = 8;
        let base: Vec<u8> = (0..61u8).collect(); // ragged tail chunk
        let mut new = base.clone();
        new[10] = 0xEE; // chunk 1
        new[60] = 0xDD; // tail chunk 7 (5 bytes)
        let prev = ProcDigests {
            payload_len: base.len() as u64,
            digests: digest_chunks(&base, cs),
        };
        let dirty = dirty_chunks(&prev, &new, cs);
        assert_eq!(dirty, vec![1, 7]);
        let table = build_table(5, base.len() as u64, &new, cs, &dirty);
        assert_eq!(table.payload_bytes(), 8 + 5);
        let mut delta_payload = Vec::new();
        for &i in &dirty {
            let start = i * cs;
            let end = (start + cs).min(new.len());
            delta_payload.extend_from_slice(&new[start..end]);
        }
        let mut out = Vec::new();
        apply(&base, &table, &delta_payload, &mut out).unwrap();
        assert_eq!(out, new);
    }

    #[test]
    fn apply_handles_growth_and_shrink() {
        let cs = 4;
        let base: Vec<u8> = vec![1; 12];
        // grow to 18 bytes: chunks 2 (changed), 3, 4 dirty
        let mut grown = vec![1u8; 18];
        grown[8..].fill(7);
        let prev = ProcDigests { payload_len: 12, digests: digest_chunks(&base, cs) };
        let dirty = dirty_chunks(&prev, &grown, cs);
        let table = build_table(1, 12, &grown, cs, &dirty);
        let mut dp = Vec::new();
        for &i in &dirty {
            dp.extend_from_slice(&grown[i * cs..(i * cs + cs).min(grown.len())]);
        }
        let mut out = Vec::new();
        apply(&base, &table, &dp, &mut out).unwrap();
        assert_eq!(out, grown);
        // shrink back down to 6 bytes
        let shrunk = vec![2u8; 6];
        let prev = ProcDigests { payload_len: 18, digests: digest_chunks(&grown, cs) };
        let dirty = dirty_chunks(&prev, &shrunk, cs);
        let table = build_table(2, 18, &shrunk, cs, &dirty);
        let mut dp = Vec::new();
        for &i in &dirty {
            dp.extend_from_slice(&shrunk[i * cs..(i * cs + cs).min(shrunk.len())]);
        }
        apply(&grown, &table, &dp, &mut out).unwrap();
        assert_eq!(out, shrunk);
    }

    #[test]
    fn apply_rejects_corrupt_tables() {
        let base = vec![0u8; 16];
        let good = DeltaTable {
            base_seq: 1,
            base_len: 16,
            full_len: 16,
            chunk_size: 8,
            chunks: vec![ChunkRef { index: 0, offset: 0, len: 8 }],
        };
        let mut out = Vec::new();
        apply(&base, &good, &[5u8; 8], &mut out).unwrap();
        // wrong base length
        assert!(apply(&base[..8], &good, &[5u8; 8], &mut out).is_err());
        // wrong delta payload length
        assert!(apply(&base, &good, &[5u8; 7], &mut out).is_err());
        // chunk overruns the payload
        let bad = DeltaTable {
            chunks: vec![ChunkRef { index: 3, offset: 0, len: 8 }],
            ..good.clone()
        };
        assert!(apply(&base, &bad, &[5u8; 8], &mut out).is_err());
        // short chunk that is not the tail
        let bad = DeltaTable {
            chunks: vec![ChunkRef { index: 0, offset: 0, len: 4 }],
            ..good.clone()
        };
        assert!(apply(&base, &bad, &[5u8; 4], &mut out).is_err());
    }

    #[test]
    fn tracker_eligibility_and_chain_bound() {
        let policy = DeltaPolicy { chunk_size: 8, max_dirty_ratio: 0.5, max_chain: 2 };
        let mut t = Tracker::new(8);
        assert!(!t.delta_eligible(1, &policy), "no base yet");
        let digs = vec![ProcDigests { payload_len: 4, digests: vec![1] }];
        t.commit(1, digs.clone(), false);
        assert!(t.delta_eligible(1, &policy));
        assert!(!t.delta_eligible(2, &policy), "proc count mismatch");
        t.commit(2, digs.clone(), true);
        assert_eq!(t.chain_len, 1);
        t.commit(3, digs.clone(), true);
        assert!(!t.delta_eligible(1, &policy), "chain bound reached");
        t.commit(4, digs.clone(), false); // full cut re-roots
        assert_eq!(t.chain_len, 0);
        assert!(t.delta_eligible(1, &policy));
        t.reset();
        assert!(!t.delta_eligible(1, &policy));
        // chunk-size mismatch (policy changed) disqualifies
        let mut t = Tracker::new(16);
        t.commit(1, digs, false);
        assert!(!t.delta_eligible(1, &policy));
    }
}

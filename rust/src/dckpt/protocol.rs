//! Sim-mode timing model of the DMTCP checkpoint/restart protocol.
//!
//! Fig 3b decomposes a checkpoint into "DMTCP writes the checkpoint image
//! to local storage; and each VM uploads the image to the remote file
//! system" (§7.1); Fig 3c's restart is the mirror image, destabilized by
//! simultaneous downloads.  This module computes the *local* phases
//! (suspend broadcast, drain, local disk write, restart re-coordination);
//! the *network* phases (upload/download) are issued as netsim flows by
//! the sim driver using these byte counts.

use crate::util::rng::Rng;

/// Timing parameters of the process-level checkpointer.
#[derive(Debug, Clone)]
pub struct DckptParams {
    /// One coordinator→daemon control hop (s).
    pub ctrl_hop: f64,
    /// Per-process quiesce acknowledgement jitter sigma (lognormal).
    pub ctrl_sigma: f64,
    /// In-flight bytes to drain per process pair (B).
    pub drain_bytes_per_proc: f64,
    /// Drain channel bandwidth (B/s) — TCP buffers empty fast.
    pub drain_bw: f64,
    /// Local disk write bandwidth per VM (B/s); the paper's VMs write to
    /// the node-local disk first (§5.2 "fast local storage").
    pub local_disk_bw: f64,
    /// Restart: per-process re-registration with the new coordinator (s).
    pub reconnect_time: f64,
    /// Restart: barrier overhead once all processes reconnected (s).
    pub restart_barrier: f64,
}

impl Default for DckptParams {
    fn default() -> Self {
        DckptParams {
            ctrl_hop: 0.002,
            ctrl_sigma: 0.3,
            drain_bytes_per_proc: 4e6,
            drain_bw: 1.0e8,
            local_disk_bw: 1.5e8, // ~150 MB/s local disk
            reconnect_time: 0.15,
            restart_barrier: 0.5,
        }
    }
}

/// Breakdown of the local (pre-upload) checkpoint phases.
#[derive(Debug, Clone, Copy)]
pub struct CheckpointLocal {
    pub suspend: f64,
    pub drain: f64,
    pub local_write: f64,
}

impl CheckpointLocal {
    pub fn total(&self) -> f64 {
        self.suspend + self.drain + self.local_write
    }
}

/// Local checkpoint phases for `n` processes with `image_bytes` each.
///
/// * suspend — coordinator reaches daemons over a binary control tree:
///   2·⌈log₂ n⌉ hops plus jitter;
/// * drain — in-flight data proportional to the number of neighbour
///   pairs a process has (the LU ring: ≤ 2);
/// * local write — images stream to node-local disk in parallel, so the
///   time is one image over the disk, with the slowest-process jitter.
pub fn checkpoint_local(params: &DckptParams, rng: &mut Rng, n: usize, image_bytes: f64) -> CheckpointLocal {
    let depth = (n.max(1) as f64).log2().ceil().max(1.0);
    let suspend = 2.0 * depth * params.ctrl_hop * rng.lognormal(1.0, params.ctrl_sigma);
    let drain = if n > 1 {
        params.drain_bytes_per_proc * 2.0 / params.drain_bw
    } else {
        0.0
    };
    // parallel across VMs; straggler = max of n lognormals ~ modelled via
    // a single lognormal whose sigma grows slowly with n
    let straggler = rng.lognormal(1.0, 0.1 + 0.02 * (n as f64).log2().max(0.0));
    let local_write = image_bytes / params.local_disk_bw * straggler;
    CheckpointLocal { suspend, drain, local_write }
}

/// Local restart phases (after images are already on local disk):
/// read back from disk, re-register with the fresh coordinator, barrier.
pub fn restart_local(params: &DckptParams, rng: &mut Rng, n: usize, image_bytes: f64) -> f64 {
    let read = image_bytes / params.local_disk_bw;
    // processes reconnect one after another to the new coordinator as
    // their reads finish; the paper observes jitter because "restarted
    // processes do not join the computation concurrently" (§7.1)
    let reconnect: f64 = (0..n)
        .map(|_| params.reconnect_time * rng.lognormal(1.0, 0.4))
        .fold(0.0f64, f64::max);
    read + reconnect + params.restart_barrier
}

/// Table 2 checkpoint-size model for an LU-class application: the
/// problem state divides across processes while each image carries the
/// constant runtime overhead (DMTCP + libraries).
///
/// `class_bytes` is the single-process state size; the paper's lu.C fit
/// is ≈ 645 MB data + ≈ 10 MB constant (Table 2: 655/338/174/92/49 MB).
pub fn image_bytes_per_proc(class_bytes: f64, overhead_bytes: f64, nprocs: usize) -> f64 {
    class_bytes / nprocs.max(1) as f64 + overhead_bytes
}

/// The paper's NAS lu.C single-process data size implied by Table 2.
pub const LU_CLASS_C_BYTES: f64 = 645e6;
/// The constant per-image overhead implied by Table 2.
pub const LU_IMAGE_OVERHEAD_BYTES: f64 = 10e6;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suspend_grows_logarithmically() {
        let p = DckptParams::default();
        let mut rng = Rng::new(1);
        // average over draws to beat jitter
        let avg = |n: usize, rng: &mut Rng| -> f64 {
            (0..200).map(|_| checkpoint_local(&p, rng, n, 1e6).suspend).sum::<f64>() / 200.0
        };
        let s2 = avg(2, &mut rng);
        let s64 = avg(64, &mut rng);
        let s128 = avg(128, &mut rng);
        assert!(s64 > s2);
        // log growth: 128 vs 64 is one more level, not double
        assert!(s128 < s64 * 1.4, "s64={s64} s128={s128}");
    }

    #[test]
    fn local_write_scales_with_bytes() {
        let p = DckptParams::default();
        let mut rng = Rng::new(2);
        let small = checkpoint_local(&p, &mut rng, 4, 50e6).local_write;
        let big = checkpoint_local(&p, &mut rng, 4, 650e6).local_write;
        assert!(big > 8.0 * small, "big={big} small={small}");
    }

    #[test]
    fn single_proc_has_no_drain() {
        let p = DckptParams::default();
        let mut rng = Rng::new(3);
        assert_eq!(checkpoint_local(&p, &mut rng, 1, 1e6).drain, 0.0);
        assert!(checkpoint_local(&p, &mut rng, 2, 1e6).drain > 0.0);
    }

    #[test]
    fn table2_size_model_matches_paper_shape() {
        // paper: 655 / 338 / 174 / 92 / 49 MB for 1 / 2 / 4 / 8 / 16 procs
        let paper = [655e6, 338e6, 174e6, 92e6, 49e6];
        for (k, want) in paper.iter().enumerate() {
            let n = 1usize << k;
            let got = image_bytes_per_proc(LU_CLASS_C_BYTES, LU_IMAGE_OVERHEAD_BYTES, n);
            let rel = (got - want).abs() / want;
            assert!(rel < 0.10, "n={n}: got {got:.0}, paper {want:.0}, rel {rel:.2}");
        }
    }

    #[test]
    fn restart_has_barrier_floor() {
        let p = DckptParams::default();
        let mut rng = Rng::new(4);
        let t = restart_local(&p, &mut rng, 1, 1e3);
        assert!(t >= p.restart_barrier);
    }

    #[test]
    fn restart_jitter_grows_with_n() {
        let p = DckptParams::default();
        let mut rng = Rng::new(5);
        let spread = |n: usize, rng: &mut Rng| {
            let xs: Vec<f64> = (0..100).map(|_| restart_local(&p, rng, n, 1e6)).collect();
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
            var.sqrt()
        };
        // max of more lognormals has larger spread around a larger mean
        assert!(spread(64, &mut rng) > spread(1, &mut rng));
    }
}

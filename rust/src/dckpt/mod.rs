//! `dckpt` — the DMTCP-analog distributed checkpointer (§4.1).
//!
//! DMTCP's role in CACS: each application has a **coordinator** process
//! plus a **daemon** on every node; on checkpoint the coordinator
//! quiesces all processes, in-flight network data is drained, every
//! process writes an image of its state to local storage, and execution
//! resumes; images are lazily copied to remote storage (§5.2).  On
//! restart a *new* coordinator is started (no single point of failure,
//! §4.1) and processes reconnect after loading their images.
//!
//! This module rebuilds that interface:
//!
//! * [`DistributedApp`] — what a checkpointable distributed application
//!   looks like to the checkpointer: per-process state serialization,
//!   restoration, health and progress.  Implemented by every workload in
//!   [`crate::workloads`].
//! * [`image`] — the on-disk image format: magic + JSON header + payload
//!   + CRC-32, with a constant [`image::RUNTIME_OVERHEAD_BYTES`]
//!   modelling the libraries DMTCP bundles into real images (the reason
//!   Table 2's sizes are `data/n + c`, not `data/n`).  The hot path is
//!   streaming and zero-copy: [`image::ImageWriter`] pushes header +
//!   payload chunks into any sink with the CRC sharded across the shared
//!   thread pool, and [`image::decode_ref`] verifies and borrows the
//!   payload without copying it out.
//! * [`delta`] — the dirty-chunk incremental engine: per-chunk 64-bit
//!   digests kept between cuts, a differ that emits v2 delta images
//!   carrying only the changed chunks (full-image fallback over a dirty
//!   ratio, bounded chain length), and the chain reconstructor
//!   `restore` uses to replay a delta chain onto its full base.
//! * [`service`] — real-mode checkpoint/restore of a [`DistributedApp`]
//!   into any [`crate::storage::ObjectStore`] (two-phase: quiesce at a
//!   step barrier — the analog of DMTCP's socket drain — then stream
//!   every image chunk-at-a-time into the store's
//!   [`crate::storage::PutWriter`]).
//! * [`protocol`] — the sim-mode timing model of the same protocol
//!   (suspend broadcast, drain, local write, lazy upload; restart
//!   re-coordination), used by the figure benches.

pub mod delta;
pub mod image;
pub mod protocol;
pub mod service;

use anyhow::Result;

/// A distributed application as seen by the checkpointer and the health
/// monitor: `n` cooperating processes advancing in steps.
///
/// Implementations own all inter-process communication (e.g. the LU
/// solver's halo exchange) *between* `step()` calls, so a step boundary
/// is a consistent cut — exactly the property DMTCP's drain protocol
/// establishes before writing images.
///
/// Deliberately *not* `Send`: PJRT-backed apps hold `!Send` XLA handles,
/// so the real-mode driver constructs the app on its dedicated
/// application thread via a `Send` factory and never moves it
/// (see `coordinator::appthread`).
pub trait DistributedApp {
    /// Number of constituent processes.
    fn nprocs(&self) -> usize;

    /// Advance the whole application by one step (one solver iteration,
    /// one simulated event batch, ...).  Failed processes make this
    /// return an error.
    fn step(&mut self) -> Result<()>;

    /// Serialize process `i`'s state into an image payload.
    fn serialize_proc(&self, i: usize) -> Result<Vec<u8>>;

    /// Restore process `i` from an image payload.
    fn restore_proc(&mut self, i: usize, payload: &[u8]) -> Result<()>;

    /// The user-supplied health hook (§6.3): is process `i` healthy?
    fn proc_healthy(&self, i: usize) -> bool;

    /// Fault injection: kill process `i` (simulates VM/process loss).
    fn kill_proc(&mut self, i: usize);

    /// Completed step count.
    fn iteration(&self) -> u64;

    /// Application-level progress metric (residual, simulated seconds,
    /// ...), for logging and convergence checks.
    fn metric(&self) -> f64;

    /// Workload kind tag recorded in image headers.
    fn kind(&self) -> &'static str;
}

/// Minimal in-memory app used by checkpointer/monitor/coordinator tests:
/// each proc is a counter plus a data blob; a step increments every live
/// counter.  Public because integration tests and benches reuse it.
pub struct CounterApp {
    pub counters: Vec<Option<u64>>,
    pub blob_bytes: usize,
    pub steps: u64,
}

impl CounterApp {
    pub fn new(n: usize, blob_bytes: usize) -> CounterApp {
        CounterApp { counters: vec![Some(0); n], blob_bytes, steps: 0 }
    }
}

impl DistributedApp for CounterApp {
    fn nprocs(&self) -> usize {
        self.counters.len()
    }

    fn step(&mut self) -> Result<()> {
        for (i, c) in self.counters.iter_mut().enumerate() {
            match c {
                Some(v) => *v += 1,
                None => anyhow::bail!("proc {i} is dead"),
            }
        }
        self.steps += 1;
        Ok(())
    }

    fn serialize_proc(&self, i: usize) -> Result<Vec<u8>> {
        let v = self.counters[i].ok_or_else(|| anyhow::anyhow!("proc {i} dead"))?;
        let mut out = v.to_le_bytes().to_vec();
        out.extend(self.steps.to_le_bytes());
        out.extend(std::iter::repeat(0xAB).take(self.blob_bytes));
        Ok(out)
    }

    fn restore_proc(&mut self, i: usize, payload: &[u8]) -> Result<()> {
        anyhow::ensure!(payload.len() == 16 + self.blob_bytes, "bad payload size");
        let mut b = [0u8; 8];
        b.copy_from_slice(&payload[..8]);
        self.counters[i] = Some(u64::from_le_bytes(b));
        b.copy_from_slice(&payload[8..16]);
        self.steps = u64::from_le_bytes(b);
        Ok(())
    }

    fn proc_healthy(&self, i: usize) -> bool {
        self.counters[i].is_some()
    }

    fn kill_proc(&mut self, i: usize) {
        self.counters[i] = None;
    }

    fn iteration(&self) -> u64 {
        self.steps
    }

    fn metric(&self) -> f64 {
        self.counters.iter().flatten().sum::<u64>() as f64
    }

    fn kind(&self) -> &'static str {
        "counter"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_app_steps_and_checkpoints() {
        let mut app = CounterApp::new(3, 10);
        app.step().unwrap();
        app.step().unwrap();
        assert_eq!(app.iteration(), 2);
        let img = app.serialize_proc(1).unwrap();
        app.step().unwrap();
        app.restore_proc(1, &img).unwrap();
        assert_eq!(app.counters[1], Some(2));
    }

    #[test]
    fn dead_proc_fails_step_and_health() {
        let mut app = CounterApp::new(2, 0);
        app.kill_proc(0);
        assert!(!app.proc_healthy(0));
        assert!(app.proc_healthy(1));
        assert!(app.step().is_err());
        assert!(app.serialize_proc(0).is_err());
    }
}

//! Real-mode checkpoint/restore of a [`DistributedApp`] into an
//! [`ObjectStore`] — what the examples exercise end-to-end.
//!
//! The protocol mirrors DMTCP's (§4.1): the app is quiesced at a step
//! barrier (our consistent cut), every process's state is serialized and
//! written as an image object, then execution resumes.  Restore picks a
//! checkpoint sequence (latest by default, §6.2: "the Checkpoint Manager
//! will choose the most recent checkpoint image, by default, but a user
//! may also specify an earlier image") and loads every process.

use super::delta::{self, DeltaPolicy, ProcDigests, Tracker};
use super::image::{self, DeltaTable, ImageHeader};
use super::DistributedApp;
use crate::storage::{ObjectStore, PutWriter};
use crate::util::pool::ThreadPool;
use anyhow::{bail, Context, Result};

/// Key layout: `<app>/ckpt-<seq>/proc-<i>.img`.
pub fn image_key(app: &str, seq: u64, proc_index: usize) -> String {
    format!("{app}/ckpt-{seq}/proc-{proc_index}.img")
}

/// Upper bound on the chain walk during restore: writers force a full
/// image far earlier (`DeltaPolicy::max_chain`), so anything past this
/// is a corrupt `base_seq` cycle, not a real chain.
const MAX_RESOLVE_CHAIN: usize = 64;

/// Result of a checkpoint: per-proc image sizes plus the iteration at
/// the consistent cut (read *during* the quiesced checkpoint, so it is
/// exact — sampling progress afterwards could over-report).
#[derive(Debug, Clone)]
pub struct CheckpointReport {
    pub seq: u64,
    pub iteration: u64,
    pub image_bytes: Vec<u64>,
    /// `Some(base)` when this cut emitted at least one delta image
    /// (chained to checkpoint `base`); `None` = an all-full cut.
    pub base_seq: Option<u64>,
    /// Wire bytes of the delta images in this cut (0 for full cuts).
    pub delta_bytes: u64,
}

impl CheckpointReport {
    pub fn total_bytes(&self) -> u64 {
        self.image_bytes.iter().sum()
    }

    /// "full" or "delta" — what `GET /checkpoints` surfaces per cut.
    pub fn kind(&self) -> &'static str {
        if self.base_seq.is_some() {
            "delta"
        } else {
            "full"
        }
    }
}

/// Stream one image into the store: open the put-writer, emit the
/// header, let `body` push the payload chunks, seal CRC + object.
fn stream_image<'s, F>(
    store: &'s dyn ObjectStore,
    key: &str,
    header: &ImageHeader,
    body: F,
) -> Result<u64>
where
    F: FnOnce(&mut image::ImageWriter<Box<dyn PutWriter + 's>>) -> Result<()>,
{
    let obj = store
        .put_writer(key)
        .map_err(|e| anyhow::anyhow!("store put {key}: {e}"))?;
    let mut w = image::ImageWriter::new(obj, header)
        .with_context(|| format!("write image {key}"))?;
    body(&mut w).with_context(|| format!("write image {key}"))?;
    let (obj, wire_bytes) = w.finish().with_context(|| format!("write image {key}"))?;
    obj.finish()
        .map_err(|e| anyhow::anyhow!("store put {key}: {e}"))?;
    Ok(wire_bytes)
}

/// Write one full image for proc `i`; returns the wire byte count.
fn write_full_image(
    store: &dyn ObjectStore,
    app: &dyn DistributedApp,
    app_name: &str,
    seq: u64,
    i: usize,
    payload: &[u8],
    overhead: usize,
) -> Result<u64> {
    let header = ImageHeader {
        app: app_name.to_string(),
        proc_index: i,
        ckpt_seq: seq,
        kind: app.kind().to_string(),
        iteration: app.iteration(),
        payload_len: (payload.len() + overhead) as u64,
        delta: None,
    };
    let key = image_key(app_name, seq, i);
    stream_image(store, &key, &header, |w| {
        if payload.len() >= image::PARALLEL_CRC_MIN_BYTES {
            w.write_payload_parallel(payload, ThreadPool::shared())?;
        } else {
            w.write_payload(payload)?;
        }
        if overhead > 0 {
            w.write_zeros(overhead)?;
        }
        Ok(())
    })
}

/// Checkpoint every process of `app` into `store` under sequence `seq`.
///
/// `with_runtime_overhead` appends the modelled DMTCP library payload
/// (see [`image::RUNTIME_OVERHEAD_BYTES`]); examples use `false` to keep
/// quickstart artifacts small, the Table 2 bench uses `true`.
///
/// The write path is fully streaming: header and payload chunks flow
/// straight into the store's [`crate::storage::PutWriter`] (no wire
/// buffer is ever materialized), large payloads are CRC-hashed in
/// parallel shards on [`ThreadPool::shared`], and the runtime-overhead
/// padding is synthesized from a static zero page.
pub fn checkpoint(
    app: &dyn DistributedApp,
    store: &dyn ObjectStore,
    app_name: &str,
    seq: u64,
    with_runtime_overhead: bool,
) -> Result<CheckpointReport> {
    let mut sizes = Vec::with_capacity(app.nprocs());
    // Phase 1 (quiesce/drain) is implicit: we are between step() calls,
    // so no in-flight messages exist.  Phase 2: stream all images.
    let overhead = if with_runtime_overhead { image::RUNTIME_OVERHEAD_BYTES } else { 0 };
    for i in 0..app.nprocs() {
        let payload = app
            .serialize_proc(i)
            .with_context(|| format!("serialize proc {i}"))?;
        sizes.push(write_full_image(store, app, app_name, seq, i, &payload, overhead)?);
    }
    Ok(CheckpointReport {
        seq,
        iteration: app.iteration(),
        image_bytes: sizes,
        base_seq: None,
        delta_bytes: 0,
    })
}

/// Checkpoint with the dirty-chunk delta engine: diff each process's
/// fresh state against `tracker`'s digests from the previous cut and
/// emit a v2 delta image when the dirty ratio is at or under
/// [`DeltaPolicy::max_dirty_ratio`] — otherwise (or when the chain hit
/// [`DeltaPolicy::max_chain`], or there is no usable base) a full
/// image, so chains are self-healing and bounded.  The decision is per
/// process: one noisy proc falls back to a full image (re-rooting its
/// own chain) without forcing the quiet procs to give up their deltas.
///
/// With `allow_delta = false` every image is full but the tracker is
/// still re-based on this cut, so a later delta cut chains to *this*
/// sequence — that is what lets a migration pre-copy push a full cut
/// and then ship only the dirty chunks written while it transferred.
///
/// The tracker commits only when the whole cut succeeded; a failed cut
/// leaves the previous digests in place.
#[allow(clippy::too_many_arguments)]
pub fn checkpoint_tracked(
    app: &dyn DistributedApp,
    store: &dyn ObjectStore,
    app_name: &str,
    seq: u64,
    with_runtime_overhead: bool,
    allow_delta: bool,
    tracker: &mut Tracker,
    policy: &DeltaPolicy,
) -> Result<CheckpointReport> {
    let nprocs = app.nprocs();
    if tracker.chunk_size != policy.chunk_size {
        // the knob changed mid-flight: old digests are meaningless
        tracker.reset();
        tracker.chunk_size = policy.chunk_size;
    }
    let eligible = allow_delta && tracker.delta_eligible(nprocs, policy);
    let cs = policy.chunk_size;
    let overhead = if with_runtime_overhead { image::RUNTIME_OVERHEAD_BYTES } else { 0 };
    let mut sizes = Vec::with_capacity(nprocs);
    let mut fresh: Vec<ProcDigests> = Vec::with_capacity(nprocs);
    let mut any_delta = false;
    let mut delta_bytes = 0u64;
    for i in 0..nprocs {
        let payload = app
            .serialize_proc(i)
            .with_context(|| format!("serialize proc {i}"))?;
        let digests = delta::digest_chunks(&payload, cs);
        let mut wrote_delta = false;
        if eligible {
            let prev = &tracker.procs[i];
            let dirty = delta::dirty_from_digests(prev, &digests);
            let dirty_bytes: usize = dirty
                .iter()
                .map(|&ci| cs.min(payload.len() - ci * cs))
                .sum();
            let ratio = if payload.is_empty() {
                0.0
            } else {
                dirty_bytes as f64 / payload.len() as f64
            };
            if ratio <= policy.max_dirty_ratio {
                let base_seq = tracker.base_seq.expect("eligible implies a base");
                let table =
                    delta::build_table(base_seq, prev.payload_len, &payload, cs, &dirty);
                let header = ImageHeader {
                    app: app_name.to_string(),
                    proc_index: i,
                    ckpt_seq: seq,
                    kind: app.kind().to_string(),
                    iteration: app.iteration(),
                    payload_len: table.payload_bytes(),
                    delta: Some(table),
                };
                let key = image_key(app_name, seq, i);
                // deltas never carry the runtime-overhead padding: the
                // modelled DMTCP libraries are immutable, so only the
                // full base image pays that constant
                let wire = stream_image(store, &key, &header, |w| {
                    for &ci in &dirty {
                        let start = ci * cs;
                        let end = (start + cs).min(payload.len());
                        w.write_payload(&payload[start..end])?;
                    }
                    Ok(())
                })?;
                delta_bytes += wire;
                sizes.push(wire);
                wrote_delta = true;
                any_delta = true;
            }
        }
        if !wrote_delta {
            sizes.push(write_full_image(store, app, app_name, seq, i, &payload, overhead)?);
        }
        fresh.push(ProcDigests { payload_len: payload.len() as u64, digests });
    }
    let base_seq = if any_delta { tracker.base_seq } else { None };
    tracker.commit(seq, fresh, any_delta);
    Ok(CheckpointReport {
        seq,
        iteration: app.iteration(),
        image_bytes: sizes,
        base_seq,
        delta_bytes,
    })
}

/// All checkpoint sequences available for `app_name`, ascending.
pub fn list_checkpoints(store: &dyn ObjectStore, app_name: &str) -> Result<Vec<u64>> {
    let keys = store
        .list(&format!("{app_name}/"))
        .map_err(|e| anyhow::anyhow!("store list: {e}"))?;
    let mut seqs: Vec<u64> = keys
        .iter()
        .filter_map(|k| {
            let rest = k.strip_prefix(&format!("{app_name}/ckpt-"))?;
            let (seq, _) = rest.split_once('/')?;
            seq.parse().ok()
        })
        .collect();
    seqs.sort();
    seqs.dedup();
    Ok(seqs)
}

/// Read + CRC-verify one image into `buf` (reused across calls so an
/// n-proc restore allocates one buffer, not n) and hand back the
/// zero-copy reader over it.
fn read_image_into<'a>(
    store: &dyn ObjectStore,
    app_name: &str,
    seq: u64,
    proc_index: usize,
    buf: &'a mut Vec<u8>,
) -> Result<image::ImageReader<'a>> {
    let key = image_key(app_name, seq, proc_index);
    buf.clear();
    store
        .get_into(&key, buf)
        .map_err(|e| anyhow::anyhow!("store get {key}: {e}"))?;
    let reader = image::ImageReader::new(buf).with_context(|| format!("decode {key}"))?;
    reader.verify_auto().with_context(|| format!("decode {key}"))?;
    let header = reader.header();
    if header.proc_index != proc_index {
        bail!("image {key} is for proc {}, expected {proc_index}", header.proc_index);
    }
    Ok(reader)
}

/// Restore one proc from a full-image payload (strip the
/// runtime-overhead padding first when it looks present; fall back to
/// the unstripped bytes).
fn restore_full(app: &mut dyn DistributedApp, i: usize, payload: &[u8]) -> Result<()> {
    let original = if payload.len() >= image::RUNTIME_OVERHEAD_BYTES
        && payload[payload.len() - 1] == 0
    {
        // runtime-overhead padding is zeros; workloads validate the
        // payload length themselves, so try stripped first.
        image::strip_runtime_overhead(payload)
    } else {
        payload
    };
    match app.restore_proc(i, original) {
        Ok(()) => Ok(()),
        // fall back to the unstripped payload (image without padding)
        Err(_) => app
            .restore_proc(i, payload)
            .with_context(|| format!("restore proc {i}")),
    }
}

/// Restore `app` from checkpoint `seq` (or the most recent when `None`).
/// Returns the sequence used.
///
/// Delta images resolve their chain per proc: walk `base_seq` links
/// back to the nearest full image, seed the state from its payload
/// (stripped of runtime-overhead padding when the chain's `base_len`
/// says the diff ran on the raw state), then replay the deltas forward
/// oldest-first.  The walk is capped at [`MAX_RESOLVE_CHAIN`] so a
/// corrupt `base_seq` cycle fails instead of looping.  All image reads
/// go through one reused scratch buffer.
pub fn restore(
    app: &mut dyn DistributedApp,
    store: &dyn ObjectStore,
    app_name: &str,
    seq: Option<u64>,
) -> Result<u64> {
    let seq = match seq {
        Some(s) => s,
        None => *list_checkpoints(store, app_name)?
            .last()
            .context("no checkpoints available")?,
    };
    // one scratch buffer for every image read, plus two state buffers
    // for chain replay — an n-proc restore allocates once, not n times
    let mut scratch: Vec<u8> = Vec::new();
    let mut state: Vec<u8> = Vec::new();
    let mut rebuilt: Vec<u8> = Vec::new();
    for i in 0..app.nprocs() {
        // tip image: full images restore straight from the borrowed
        // payload; delta images seed the chain walk
        let tip: Option<(DeltaTable, Vec<u8>)> = {
            let reader = read_image_into(store, app_name, seq, i, &mut scratch)?;
            let header = reader.header();
            if header.kind != app.kind() {
                bail!("image kind {:?} != app kind {:?}", header.kind, app.kind());
            }
            match &header.delta {
                Some(t) => Some((t.clone(), reader.payload().to_vec())),
                None => {
                    restore_full(app, i, reader.payload())?;
                    None
                }
            }
        };
        let Some(tip) = tip else { continue };
        // collect delta links newest → oldest until the full base
        let mut links: Vec<(DeltaTable, Vec<u8>)> = vec![tip];
        loop {
            if links.len() > MAX_RESOLVE_CHAIN {
                bail!("delta chain for proc {i} exceeds {MAX_RESOLVE_CHAIN} links (cycle?)");
            }
            let base_seq = links.last().expect("non-empty").0.base_seq;
            let next: Option<(DeltaTable, Vec<u8>)> = {
                let reader = read_image_into(store, app_name, base_seq, i, &mut scratch)?;
                let header = reader.header();
                if header.kind != app.kind() {
                    bail!("image kind {:?} != app kind {:?}", header.kind, app.kind());
                }
                match &header.delta {
                    Some(t) => Some((t.clone(), reader.payload().to_vec())),
                    None => {
                        // full base found: seed the reconstruction state
                        // with its raw payload (the diff ran on the
                        // unpadded state, so strip padding when present)
                        let deepest = &links.last().expect("non-empty").0;
                        let payload = reader.payload();
                        let base = if payload.len() as u64 == deepest.base_len {
                            payload
                        } else if payload.len()
                            == deepest.base_len as usize + image::RUNTIME_OVERHEAD_BYTES
                        {
                            image::strip_runtime_overhead(payload)
                        } else {
                            bail!(
                                "delta chain for proc {i}: base ckpt-{base_seq} is {} bytes, chain expects {}",
                                payload.len(),
                                deepest.base_len
                            );
                        };
                        state.clear();
                        state.extend_from_slice(base);
                        None
                    }
                }
            };
            match next {
                Some(link) => links.push(link),
                None => break,
            }
        }
        // replay oldest-first onto the base state
        for (table, delta_payload) in links.iter().rev() {
            delta::apply(&state, table, delta_payload, &mut rebuilt)
                .with_context(|| format!("apply delta ckpt-{} proc {i}", table.base_seq))?;
            std::mem::swap(&mut state, &mut rebuilt);
        }
        app.restore_proc(i, &state)
            .with_context(|| format!("restore proc {i}"))?;
    }
    Ok(seq)
}

/// Delete every image of a checkpoint (§5.4 termination step 2 deletes
/// all of them; the REST DELETE on one checkpoint uses this too).
pub fn delete_checkpoint(store: &dyn ObjectStore, app_name: &str, seq: u64) -> Result<usize> {
    store
        .delete_prefix(&format!("{app_name}/ckpt-{seq}/"))
        .map_err(|e| anyhow::anyhow!("store delete: {e}"))
}

/// Delete all images of an application.
pub fn delete_all(store: &dyn ObjectStore, app_name: &str) -> Result<usize> {
    store
        .delete_prefix(&format!("{app_name}/"))
        .map_err(|e| anyhow::anyhow!("store delete: {e}"))
}

/// Stream one checkpoint image into an arbitrary sink.  The migration
/// orchestrator pipes this straight into a chunked HTTP upload
/// ([`crate::util::http::Client::post_stream`]), so an image crosses
/// from store to socket without ever being materialized in memory.
pub fn copy_image_to(
    store: &dyn ObjectStore,
    app_name: &str,
    seq: u64,
    proc_index: usize,
    out: &mut dyn std::io::Write,
) -> Result<u64> {
    let key = image_key(app_name, seq, proc_index);
    store
        .get_into(&key, out)
        .map_err(|e| anyhow::anyhow!("store get {key}: {e}"))
}

/// Copy a checkpoint between stores (cloning/migration, §5.3: images are
/// uploaded to the destination CACS, then restarted there).
pub fn copy_checkpoint(
    src: &dyn ObjectStore,
    dst: &dyn ObjectStore,
    app_name: &str,
    seq: u64,
    dst_app_name: &str,
) -> Result<usize> {
    let prefix = format!("{app_name}/ckpt-{seq}/");
    let keys = src
        .list(&prefix)
        .map_err(|e| anyhow::anyhow!("store list: {e}"))?;
    if keys.is_empty() {
        bail!("checkpoint {app_name}/ckpt-{seq} not found");
    }
    for key in &keys {
        let dst_key = key.replacen(app_name, dst_app_name, 1);
        // stream source → destination; no whole-image buffer in between
        let mut w = dst
            .put_writer(&dst_key)
            .map_err(|e| anyhow::anyhow!("put {dst_key}: {e}"))?;
        src.get_into(key, &mut w)
            .map_err(|e| anyhow::anyhow!("copy {key} -> {dst_key}: {e}"))?;
        w.finish()
            .map_err(|e| anyhow::anyhow!("put {dst_key}: {e}"))?;
    }
    Ok(keys.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dckpt::CounterApp;
    use crate::storage::mem::MemStore;

    #[test]
    fn checkpoint_restore_roundtrip() {
        let store = MemStore::new();
        let mut app = CounterApp::new(4, 100);
        for _ in 0..10 {
            app.step().unwrap();
        }
        let report = checkpoint(&app, &store, "app-1", 1, false).unwrap();
        assert_eq!(report.image_bytes.len(), 4);
        assert_eq!(report.iteration, 10, "iteration recorded at the cut");
        for _ in 0..5 {
            app.step().unwrap();
        }
        assert_eq!(app.iteration(), 15);
        let seq = restore(&mut app, &store, "app-1", None).unwrap();
        assert_eq!(seq, 1);
        assert_eq!(app.iteration(), 10);
        assert_eq!(app.counters, vec![Some(10); 4]);
    }

    #[test]
    fn latest_checkpoint_chosen_by_default() {
        let store = MemStore::new();
        let mut app = CounterApp::new(2, 0);
        app.step().unwrap();
        checkpoint(&app, &store, "a", 1, false).unwrap();
        app.step().unwrap();
        checkpoint(&app, &store, "a", 2, false).unwrap();
        app.step().unwrap();
        let seq = restore(&mut app, &store, "a", None).unwrap();
        assert_eq!(seq, 2);
        assert_eq!(app.iteration(), 2);
        // explicit earlier image (§6.2)
        let seq = restore(&mut app, &store, "a", Some(1)).unwrap();
        assert_eq!(seq, 1);
        assert_eq!(app.iteration(), 1);
    }

    #[test]
    fn list_checkpoints_sorted() {
        let store = MemStore::new();
        let app = CounterApp::new(2, 0);
        for seq in [3u64, 1, 2] {
            checkpoint(&app, &store, "a", seq, false).unwrap();
        }
        assert_eq!(list_checkpoints(&store, "a").unwrap(), vec![1, 2, 3]);
        assert!(list_checkpoints(&store, "other").unwrap().is_empty());
    }

    #[test]
    fn restore_missing_fails() {
        let store = MemStore::new();
        let mut app = CounterApp::new(2, 0);
        assert!(restore(&mut app, &store, "ghost", None).is_err());
        assert!(restore(&mut app, &store, "ghost", Some(7)).is_err());
    }

    #[test]
    fn kind_mismatch_rejected() {
        let store = MemStore::new();
        let app = CounterApp::new(1, 0);
        checkpoint(&app, &store, "a", 1, false).unwrap();
        // a different kind of app must refuse these images
        struct OtherApp(CounterApp);
        impl DistributedApp for OtherApp {
            fn nprocs(&self) -> usize {
                self.0.nprocs()
            }
            fn step(&mut self) -> anyhow::Result<()> {
                self.0.step()
            }
            fn serialize_proc(&self, i: usize) -> anyhow::Result<Vec<u8>> {
                self.0.serialize_proc(i)
            }
            fn restore_proc(&mut self, i: usize, p: &[u8]) -> anyhow::Result<()> {
                self.0.restore_proc(i, p)
            }
            fn proc_healthy(&self, i: usize) -> bool {
                self.0.proc_healthy(i)
            }
            fn kill_proc(&mut self, i: usize) {
                self.0.kill_proc(i)
            }
            fn iteration(&self) -> u64 {
                self.0.iteration()
            }
            fn metric(&self) -> f64 {
                self.0.metric()
            }
            fn kind(&self) -> &'static str {
                "other"
            }
        }
        let mut other = OtherApp(CounterApp::new(1, 0));
        let err = restore(&mut other, &store, "a", None).unwrap_err().to_string();
        assert!(err.contains("kind"), "{err}");
    }

    #[test]
    fn delete_checkpoint_and_all() {
        let store = MemStore::new();
        let app = CounterApp::new(3, 0);
        checkpoint(&app, &store, "a", 1, false).unwrap();
        checkpoint(&app, &store, "a", 2, false).unwrap();
        assert_eq!(delete_checkpoint(&store, "a", 1).unwrap(), 3);
        assert_eq!(list_checkpoints(&store, "a").unwrap(), vec![2]);
        assert_eq!(delete_all(&store, "a").unwrap(), 3);
        assert!(list_checkpoints(&store, "a").unwrap().is_empty());
    }

    #[test]
    fn copy_checkpoint_for_migration() {
        let src = MemStore::new();
        let dst = MemStore::new();
        let mut app = CounterApp::new(2, 50);
        for _ in 0..7 {
            app.step().unwrap();
        }
        checkpoint(&app, &src, "app-1", 1, false).unwrap();
        let n = copy_checkpoint(&src, &dst, "app-1", 1, "app-9").unwrap();
        assert_eq!(n, 2);
        // restore the clone on the destination under its new name
        let mut clone = CounterApp::new(2, 50);
        restore(&mut clone, &dst, "app-9", None).unwrap();
        assert_eq!(clone.iteration(), 7);
        assert!(copy_checkpoint(&src, &dst, "app-1", 99, "x").is_err());
    }

    #[test]
    fn copy_image_to_streams_exact_bytes() {
        let store = MemStore::new();
        let mut app = CounterApp::new(2, 11);
        app.step().unwrap();
        checkpoint(&app, &store, "a", 1, false).unwrap();
        let mut out = Vec::new();
        let n = copy_image_to(&store, "a", 1, 1, &mut out).unwrap();
        assert_eq!(n as usize, out.len());
        assert_eq!(out, store.get(&image_key("a", 1, 1)).unwrap());
        assert!(copy_image_to(&store, "a", 1, 9, &mut out).is_err());
    }

    #[test]
    fn streamed_images_byte_identical_to_encode() {
        // the streaming write path must put exactly the bytes the v1
        // whole-buffer encode produced, padding included
        let store = MemStore::new();
        let mut app = CounterApp::new(2, 9);
        for _ in 0..4 {
            app.step().unwrap();
        }
        for overhead in [false, true] {
            let seq = if overhead { 2 } else { 1 };
            checkpoint(&app, &store, "bytecmp", seq, overhead).unwrap();
            for i in 0..2 {
                let stored = store.get(&image_key("bytecmp", seq, i)).unwrap();
                let payload = app.serialize_proc(i).unwrap();
                let hdr = ImageHeader {
                    app: "bytecmp".into(),
                    proc_index: i,
                    ckpt_seq: seq,
                    kind: app.kind().to_string(),
                    iteration: app.iteration(),
                    payload_len: payload.len() as u64,
                    delta: None,
                };
                let expect = if overhead {
                    image::encode_with_runtime_overhead(&hdr, &payload)
                } else {
                    image::encode(&hdr, &payload)
                };
                assert_eq!(stored, expect, "overhead={overhead} proc={i}");
            }
        }
    }

    #[test]
    fn runtime_overhead_images_roundtrip() {
        let store = MemStore::new();
        let mut app = CounterApp::new(1, 64);
        app.step().unwrap();
        let report = checkpoint(&app, &store, "a", 1, true).unwrap();
        assert!(report.image_bytes[0] > image::RUNTIME_OVERHEAD_BYTES as u64);
        app.step().unwrap();
        restore(&mut app, &store, "a", None).unwrap();
        assert_eq!(app.iteration(), 1);
    }

    fn small_policy() -> DeltaPolicy {
        DeltaPolicy { chunk_size: 64, max_dirty_ratio: 0.5, max_chain: 8 }
    }

    #[test]
    fn delta_chain_checkpoints_and_restores() {
        // CounterApp payloads are 16 mutable bytes + a constant blob:
        // after the first (full) cut every later cut is a tiny delta
        let store = MemStore::new();
        let mut app = CounterApp::new(2, 4096);
        let policy = small_policy();
        let mut tracker = Tracker::new(policy.chunk_size);
        app.step().unwrap();
        let full = checkpoint_tracked(&app, &store, "a", 1, false, true, &mut tracker, &policy)
            .unwrap();
        assert_eq!(full.kind(), "full");
        assert_eq!(full.base_seq, None);
        assert_eq!(full.delta_bytes, 0);
        for seq in 2..=4u64 {
            app.step().unwrap();
            let d = checkpoint_tracked(&app, &store, "a", seq, false, true, &mut tracker, &policy)
                .unwrap();
            assert_eq!(d.kind(), "delta", "seq {seq}");
            assert_eq!(d.base_seq, Some(seq - 1));
            assert!(d.delta_bytes > 0);
            // the delta moves the dirty 64-byte chunk, not the 4 KiB blob
            assert!(
                d.total_bytes() < full.total_bytes() / 4,
                "seq {seq}: delta {} vs full {}",
                d.total_bytes(),
                full.total_bytes()
            );
        }
        let at_cut = app.counters.clone();
        let steps_at_cut = app.steps;
        for _ in 0..5 {
            app.step().unwrap();
        }
        // restore the tip of the chain: byte-identical state
        let used = restore(&mut app, &store, "a", None).unwrap();
        assert_eq!(used, 4);
        assert_eq!(app.counters, at_cut);
        assert_eq!(app.steps, steps_at_cut);
        // and an interior chain link restores too
        restore(&mut app, &store, "a", Some(2)).unwrap();
        assert_eq!(app.iteration(), 2);
    }

    #[test]
    fn delta_cut_at_low_dirty_ratio_moves_under_a_fifth_of_full() {
        // acceptance: a ≤10% dirty cut must move ≤20% of the full bytes
        let store = MemStore::new();
        let mut app = CounterApp::new(1, 256 * 1024);
        let policy = DeltaPolicy { chunk_size: 4096, ..small_policy() };
        let mut tracker = Tracker::new(policy.chunk_size);
        app.step().unwrap();
        let full = checkpoint_tracked(&app, &store, "r", 1, false, true, &mut tracker, &policy)
            .unwrap();
        // one more step dirties 16 bytes of ~256 KiB (≈0.006% — far
        // under the 10% acceptance point)
        app.step().unwrap();
        let d = checkpoint_tracked(&app, &store, "r", 2, false, true, &mut tracker, &policy)
            .unwrap();
        assert_eq!(d.kind(), "delta");
        assert!(
            d.total_bytes() * 5 <= full.total_bytes(),
            "delta {} must be ≤20% of full {}",
            d.total_bytes(),
            full.total_bytes()
        );
    }

    #[test]
    fn high_dirty_ratio_falls_back_to_full() {
        struct Churn(Vec<u8>, u64);
        impl DistributedApp for Churn {
            fn nprocs(&self) -> usize {
                1
            }
            fn step(&mut self) -> anyhow::Result<()> {
                for b in self.0.iter_mut() {
                    *b = b.wrapping_add(1); // every chunk dirty
                }
                self.1 += 1;
                Ok(())
            }
            fn serialize_proc(&self, _: usize) -> anyhow::Result<Vec<u8>> {
                Ok(self.0.clone())
            }
            fn restore_proc(&mut self, _: usize, p: &[u8]) -> anyhow::Result<()> {
                self.0 = p.to_vec();
                Ok(())
            }
            fn proc_healthy(&self, _: usize) -> bool {
                true
            }
            fn kill_proc(&mut self, _: usize) {}
            fn iteration(&self) -> u64 {
                self.1
            }
            fn metric(&self) -> f64 {
                0.0
            }
            fn kind(&self) -> &'static str {
                "churn"
            }
        }
        let store = MemStore::new();
        let mut app = Churn(vec![0u8; 4096], 0);
        let policy = small_policy();
        let mut tracker = Tracker::new(policy.chunk_size);
        checkpoint_tracked(&app, &store, "c", 1, false, true, &mut tracker, &policy).unwrap();
        app.step().unwrap();
        let r = checkpoint_tracked(&app, &store, "c", 2, false, true, &mut tracker, &policy)
            .unwrap();
        assert_eq!(r.kind(), "full", "100% dirty must self-heal to a full image");
        assert_eq!(tracker.chain_len, 0);
        restore(&mut app, &store, "c", None).unwrap();
        assert_eq!(app.iteration(), 1);
    }

    #[test]
    fn chain_length_bound_forces_periodic_full() {
        let store = MemStore::new();
        let mut app = CounterApp::new(1, 2048);
        let policy = DeltaPolicy { max_chain: 3, ..small_policy() };
        let mut tracker = Tracker::new(policy.chunk_size);
        let mut kinds = vec![];
        for seq in 1..=9u64 {
            app.step().unwrap();
            let r = checkpoint_tracked(&app, &store, "b", seq, false, true, &mut tracker, &policy)
                .unwrap();
            kinds.push(r.kind());
        }
        // full, then 3 deltas, then a forced full, 3 deltas, full...
        assert_eq!(
            kinds,
            vec!["full", "delta", "delta", "delta", "full", "delta", "delta", "delta", "full"]
        );
        // the longest chain restores byte-identically
        let at_cut = app.counters.clone();
        app.step().unwrap();
        restore(&mut app, &store, "b", Some(9)).unwrap();
        assert_eq!(app.counters, at_cut);
    }

    #[test]
    fn delta_chain_with_runtime_overhead_base() {
        // the full base carries the 10 MB padding; deltas never do, and
        // chain resolution strips the base before replaying
        let store = MemStore::new();
        let mut app = CounterApp::new(1, 1024);
        let policy = small_policy();
        let mut tracker = Tracker::new(policy.chunk_size);
        app.step().unwrap();
        let full = checkpoint_tracked(&app, &store, "o", 1, true, true, &mut tracker, &policy)
            .unwrap();
        assert!(full.total_bytes() > image::RUNTIME_OVERHEAD_BYTES as u64);
        app.step().unwrap();
        let d = checkpoint_tracked(&app, &store, "o", 2, true, true, &mut tracker, &policy)
            .unwrap();
        assert_eq!(d.kind(), "delta");
        assert!(
            d.total_bytes() < 4096,
            "delta must not carry the padding: {} bytes",
            d.total_bytes()
        );
        let counters = app.counters.clone();
        app.step().unwrap();
        restore(&mut app, &store, "o", Some(2)).unwrap();
        assert_eq!(app.counters, counters);
        assert_eq!(app.iteration(), 2);
    }

    #[test]
    fn full_cut_with_tracker_rebases_the_chain() {
        // allow_delta=false writes full images but re-bases the tracker,
        // so the next delta chains to the full cut (the migration
        // pre-copy pattern: full while running, delta at the barrier)
        let store = MemStore::new();
        let mut app = CounterApp::new(1, 2048);
        let policy = small_policy();
        let mut tracker = Tracker::new(policy.chunk_size);
        app.step().unwrap();
        let full = checkpoint_tracked(&app, &store, "p", 7, false, false, &mut tracker, &policy)
            .unwrap();
        assert_eq!(full.kind(), "full");
        app.step().unwrap();
        let d = checkpoint_tracked(&app, &store, "p", 8, false, true, &mut tracker, &policy)
            .unwrap();
        assert_eq!(d.base_seq, Some(7));
        let counters = app.counters.clone();
        app.step().unwrap();
        restore(&mut app, &store, "p", Some(8)).unwrap();
        assert_eq!(app.counters, counters);
    }

    #[test]
    fn tracker_reset_re_roots_with_a_full_image() {
        let store = MemStore::new();
        let mut app = CounterApp::new(1, 2048);
        let policy = small_policy();
        let mut tracker = Tracker::new(policy.chunk_size);
        app.step().unwrap();
        checkpoint_tracked(&app, &store, "t", 1, false, true, &mut tracker, &policy).unwrap();
        tracker.reset(); // e.g. after a restore or a deleted base
        app.step().unwrap();
        let r = checkpoint_tracked(&app, &store, "t", 2, false, true, &mut tracker, &policy)
            .unwrap();
        assert_eq!(r.kind(), "full");
    }

    #[test]
    fn broken_chain_fails_loud_not_corrupt() {
        let store = MemStore::new();
        let mut app = CounterApp::new(1, 2048);
        let policy = small_policy();
        let mut tracker = Tracker::new(policy.chunk_size);
        app.step().unwrap();
        checkpoint_tracked(&app, &store, "x", 1, false, true, &mut tracker, &policy).unwrap();
        app.step().unwrap();
        checkpoint_tracked(&app, &store, "x", 2, false, true, &mut tracker, &policy).unwrap();
        // delete the full base out from under the delta
        delete_checkpoint(&store, "x", 1).unwrap();
        let err = restore(&mut app, &store, "x", Some(2)).unwrap_err().to_string();
        assert!(err.contains("ckpt-1"), "{err}");
    }
}

//! Real-mode checkpoint/restore of a [`DistributedApp`] into an
//! [`ObjectStore`] — what the examples exercise end-to-end.
//!
//! The protocol mirrors DMTCP's (§4.1): the app is quiesced at a step
//! barrier (our consistent cut), every process's state is serialized and
//! written as an image object, then execution resumes.  Restore picks a
//! checkpoint sequence (latest by default, §6.2: "the Checkpoint Manager
//! will choose the most recent checkpoint image, by default, but a user
//! may also specify an earlier image") and loads every process.

use super::image::{self, ImageHeader};
use super::DistributedApp;
use crate::storage::ObjectStore;
use crate::util::pool::ThreadPool;
use anyhow::{bail, Context, Result};

/// Key layout: `<app>/ckpt-<seq>/proc-<i>.img`.
pub fn image_key(app: &str, seq: u64, proc_index: usize) -> String {
    format!("{app}/ckpt-{seq}/proc-{proc_index}.img")
}

/// Result of a checkpoint: per-proc image sizes plus the iteration at
/// the consistent cut (read *during* the quiesced checkpoint, so it is
/// exact — sampling progress afterwards could over-report).
#[derive(Debug, Clone)]
pub struct CheckpointReport {
    pub seq: u64,
    pub iteration: u64,
    pub image_bytes: Vec<u64>,
}

impl CheckpointReport {
    pub fn total_bytes(&self) -> u64 {
        self.image_bytes.iter().sum()
    }
}

/// Checkpoint every process of `app` into `store` under sequence `seq`.
///
/// `with_runtime_overhead` appends the modelled DMTCP library payload
/// (see [`image::RUNTIME_OVERHEAD_BYTES`]); examples use `false` to keep
/// quickstart artifacts small, the Table 2 bench uses `true`.
///
/// The write path is fully streaming: header and payload chunks flow
/// straight into the store's [`crate::storage::PutWriter`] (no wire
/// buffer is ever materialized), large payloads are CRC-hashed in
/// parallel shards on [`ThreadPool::shared`], and the runtime-overhead
/// padding is synthesized from a static zero page.
pub fn checkpoint(
    app: &dyn DistributedApp,
    store: &dyn ObjectStore,
    app_name: &str,
    seq: u64,
    with_runtime_overhead: bool,
) -> Result<CheckpointReport> {
    let mut sizes = Vec::with_capacity(app.nprocs());
    // Phase 1 (quiesce/drain) is implicit: we are between step() calls,
    // so no in-flight messages exist.  Phase 2: stream all images.
    for i in 0..app.nprocs() {
        let payload = app
            .serialize_proc(i)
            .with_context(|| format!("serialize proc {i}"))?;
        let overhead = if with_runtime_overhead { image::RUNTIME_OVERHEAD_BYTES } else { 0 };
        let header = ImageHeader {
            app: app_name.to_string(),
            proc_index: i,
            ckpt_seq: seq,
            kind: app.kind().to_string(),
            iteration: app.iteration(),
            payload_len: (payload.len() + overhead) as u64,
        };
        let key = image_key(app_name, seq, i);
        let mut obj = store
            .put_writer(&key)
            .map_err(|e| anyhow::anyhow!("store put {key}: {e}"))?;
        let mut w = image::ImageWriter::new(&mut obj, &header)
            .with_context(|| format!("write image {key}"))?;
        if payload.len() >= image::PARALLEL_CRC_MIN_BYTES {
            w.write_payload_parallel(&payload, ThreadPool::shared())
                .with_context(|| format!("write image {key}"))?;
        } else {
            w.write_payload(&payload)
                .with_context(|| format!("write image {key}"))?;
        }
        if overhead > 0 {
            w.write_zeros(overhead)
                .with_context(|| format!("write image {key}"))?;
        }
        let (_, wire_bytes) = w.finish().with_context(|| format!("write image {key}"))?;
        obj.finish()
            .map_err(|e| anyhow::anyhow!("store put {key}: {e}"))?;
        sizes.push(wire_bytes);
    }
    Ok(CheckpointReport { seq, iteration: app.iteration(), image_bytes: sizes })
}

/// All checkpoint sequences available for `app_name`, ascending.
pub fn list_checkpoints(store: &dyn ObjectStore, app_name: &str) -> Result<Vec<u64>> {
    let keys = store
        .list(&format!("{app_name}/"))
        .map_err(|e| anyhow::anyhow!("store list: {e}"))?;
    let mut seqs: Vec<u64> = keys
        .iter()
        .filter_map(|k| {
            let rest = k.strip_prefix(&format!("{app_name}/ckpt-"))?;
            let (seq, _) = rest.split_once('/')?;
            seq.parse().ok()
        })
        .collect();
    seqs.sort();
    seqs.dedup();
    Ok(seqs)
}

/// Restore `app` from checkpoint `seq` (or the most recent when `None`).
/// Returns the sequence used.
pub fn restore(
    app: &mut dyn DistributedApp,
    store: &dyn ObjectStore,
    app_name: &str,
    seq: Option<u64>,
) -> Result<u64> {
    let seq = match seq {
        Some(s) => s,
        None => *list_checkpoints(store, app_name)?
            .last()
            .context("no checkpoints available")?,
    };
    for i in 0..app.nprocs() {
        let key = image_key(app_name, seq, i);
        let data = store
            .get(&key)
            .map_err(|e| anyhow::anyhow!("store get {key}: {e}"))?;
        // zero-copy decode: parse, verify CRC (parallel shards for big
        // images), and borrow the payload straight out of `data`
        let reader = image::ImageReader::new(&data).with_context(|| format!("decode {key}"))?;
        reader.verify_auto().with_context(|| format!("decode {key}"))?;
        let header = reader.header();
        if header.proc_index != i {
            bail!("image {key} is for proc {}, expected {i}", header.proc_index);
        }
        if header.kind != app.kind() {
            bail!("image kind {:?} != app kind {:?}", header.kind, app.kind());
        }
        let payload = reader.payload();
        let original = if payload.len() >= image::RUNTIME_OVERHEAD_BYTES
            && payload[payload.len() - 1] == 0
        {
            // runtime-overhead padding is zeros; workloads validate the
            // payload length themselves, so try stripped first.
            image::strip_runtime_overhead(payload)
        } else {
            payload
        };
        match app.restore_proc(i, original) {
            Ok(()) => {}
            // fall back to the unstripped payload (image without padding)
            Err(_) => app
                .restore_proc(i, payload)
                .with_context(|| format!("restore proc {i}"))?,
        }
    }
    Ok(seq)
}

/// Delete every image of a checkpoint (§5.4 termination step 2 deletes
/// all of them; the REST DELETE on one checkpoint uses this too).
pub fn delete_checkpoint(store: &dyn ObjectStore, app_name: &str, seq: u64) -> Result<usize> {
    store
        .delete_prefix(&format!("{app_name}/ckpt-{seq}/"))
        .map_err(|e| anyhow::anyhow!("store delete: {e}"))
}

/// Delete all images of an application.
pub fn delete_all(store: &dyn ObjectStore, app_name: &str) -> Result<usize> {
    store
        .delete_prefix(&format!("{app_name}/"))
        .map_err(|e| anyhow::anyhow!("store delete: {e}"))
}

/// Stream one checkpoint image into an arbitrary sink.  The migration
/// orchestrator pipes this straight into a chunked HTTP upload
/// ([`crate::util::http::Client::post_stream`]), so an image crosses
/// from store to socket without ever being materialized in memory.
pub fn copy_image_to(
    store: &dyn ObjectStore,
    app_name: &str,
    seq: u64,
    proc_index: usize,
    out: &mut dyn std::io::Write,
) -> Result<u64> {
    let key = image_key(app_name, seq, proc_index);
    store
        .get_into(&key, out)
        .map_err(|e| anyhow::anyhow!("store get {key}: {e}"))
}

/// Copy a checkpoint between stores (cloning/migration, §5.3: images are
/// uploaded to the destination CACS, then restarted there).
pub fn copy_checkpoint(
    src: &dyn ObjectStore,
    dst: &dyn ObjectStore,
    app_name: &str,
    seq: u64,
    dst_app_name: &str,
) -> Result<usize> {
    let prefix = format!("{app_name}/ckpt-{seq}/");
    let keys = src
        .list(&prefix)
        .map_err(|e| anyhow::anyhow!("store list: {e}"))?;
    if keys.is_empty() {
        bail!("checkpoint {app_name}/ckpt-{seq} not found");
    }
    for key in &keys {
        let dst_key = key.replacen(app_name, dst_app_name, 1);
        // stream source → destination; no whole-image buffer in between
        let mut w = dst
            .put_writer(&dst_key)
            .map_err(|e| anyhow::anyhow!("put {dst_key}: {e}"))?;
        src.get_into(key, &mut w)
            .map_err(|e| anyhow::anyhow!("copy {key} -> {dst_key}: {e}"))?;
        w.finish()
            .map_err(|e| anyhow::anyhow!("put {dst_key}: {e}"))?;
    }
    Ok(keys.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dckpt::CounterApp;
    use crate::storage::mem::MemStore;

    #[test]
    fn checkpoint_restore_roundtrip() {
        let store = MemStore::new();
        let mut app = CounterApp::new(4, 100);
        for _ in 0..10 {
            app.step().unwrap();
        }
        let report = checkpoint(&app, &store, "app-1", 1, false).unwrap();
        assert_eq!(report.image_bytes.len(), 4);
        assert_eq!(report.iteration, 10, "iteration recorded at the cut");
        for _ in 0..5 {
            app.step().unwrap();
        }
        assert_eq!(app.iteration(), 15);
        let seq = restore(&mut app, &store, "app-1", None).unwrap();
        assert_eq!(seq, 1);
        assert_eq!(app.iteration(), 10);
        assert_eq!(app.counters, vec![Some(10); 4]);
    }

    #[test]
    fn latest_checkpoint_chosen_by_default() {
        let store = MemStore::new();
        let mut app = CounterApp::new(2, 0);
        app.step().unwrap();
        checkpoint(&app, &store, "a", 1, false).unwrap();
        app.step().unwrap();
        checkpoint(&app, &store, "a", 2, false).unwrap();
        app.step().unwrap();
        let seq = restore(&mut app, &store, "a", None).unwrap();
        assert_eq!(seq, 2);
        assert_eq!(app.iteration(), 2);
        // explicit earlier image (§6.2)
        let seq = restore(&mut app, &store, "a", Some(1)).unwrap();
        assert_eq!(seq, 1);
        assert_eq!(app.iteration(), 1);
    }

    #[test]
    fn list_checkpoints_sorted() {
        let store = MemStore::new();
        let app = CounterApp::new(2, 0);
        for seq in [3u64, 1, 2] {
            checkpoint(&app, &store, "a", seq, false).unwrap();
        }
        assert_eq!(list_checkpoints(&store, "a").unwrap(), vec![1, 2, 3]);
        assert!(list_checkpoints(&store, "other").unwrap().is_empty());
    }

    #[test]
    fn restore_missing_fails() {
        let store = MemStore::new();
        let mut app = CounterApp::new(2, 0);
        assert!(restore(&mut app, &store, "ghost", None).is_err());
        assert!(restore(&mut app, &store, "ghost", Some(7)).is_err());
    }

    #[test]
    fn kind_mismatch_rejected() {
        let store = MemStore::new();
        let app = CounterApp::new(1, 0);
        checkpoint(&app, &store, "a", 1, false).unwrap();
        // a different kind of app must refuse these images
        struct OtherApp(CounterApp);
        impl DistributedApp for OtherApp {
            fn nprocs(&self) -> usize {
                self.0.nprocs()
            }
            fn step(&mut self) -> anyhow::Result<()> {
                self.0.step()
            }
            fn serialize_proc(&self, i: usize) -> anyhow::Result<Vec<u8>> {
                self.0.serialize_proc(i)
            }
            fn restore_proc(&mut self, i: usize, p: &[u8]) -> anyhow::Result<()> {
                self.0.restore_proc(i, p)
            }
            fn proc_healthy(&self, i: usize) -> bool {
                self.0.proc_healthy(i)
            }
            fn kill_proc(&mut self, i: usize) {
                self.0.kill_proc(i)
            }
            fn iteration(&self) -> u64 {
                self.0.iteration()
            }
            fn metric(&self) -> f64 {
                self.0.metric()
            }
            fn kind(&self) -> &'static str {
                "other"
            }
        }
        let mut other = OtherApp(CounterApp::new(1, 0));
        let err = restore(&mut other, &store, "a", None).unwrap_err().to_string();
        assert!(err.contains("kind"), "{err}");
    }

    #[test]
    fn delete_checkpoint_and_all() {
        let store = MemStore::new();
        let app = CounterApp::new(3, 0);
        checkpoint(&app, &store, "a", 1, false).unwrap();
        checkpoint(&app, &store, "a", 2, false).unwrap();
        assert_eq!(delete_checkpoint(&store, "a", 1).unwrap(), 3);
        assert_eq!(list_checkpoints(&store, "a").unwrap(), vec![2]);
        assert_eq!(delete_all(&store, "a").unwrap(), 3);
        assert!(list_checkpoints(&store, "a").unwrap().is_empty());
    }

    #[test]
    fn copy_checkpoint_for_migration() {
        let src = MemStore::new();
        let dst = MemStore::new();
        let mut app = CounterApp::new(2, 50);
        for _ in 0..7 {
            app.step().unwrap();
        }
        checkpoint(&app, &src, "app-1", 1, false).unwrap();
        let n = copy_checkpoint(&src, &dst, "app-1", 1, "app-9").unwrap();
        assert_eq!(n, 2);
        // restore the clone on the destination under its new name
        let mut clone = CounterApp::new(2, 50);
        restore(&mut clone, &dst, "app-9", None).unwrap();
        assert_eq!(clone.iteration(), 7);
        assert!(copy_checkpoint(&src, &dst, "app-1", 99, "x").is_err());
    }

    #[test]
    fn copy_image_to_streams_exact_bytes() {
        let store = MemStore::new();
        let mut app = CounterApp::new(2, 11);
        app.step().unwrap();
        checkpoint(&app, &store, "a", 1, false).unwrap();
        let mut out = Vec::new();
        let n = copy_image_to(&store, "a", 1, 1, &mut out).unwrap();
        assert_eq!(n as usize, out.len());
        assert_eq!(out, store.get(&image_key("a", 1, 1)).unwrap());
        assert!(copy_image_to(&store, "a", 1, 9, &mut out).is_err());
    }

    #[test]
    fn streamed_images_byte_identical_to_encode() {
        // the streaming write path must put exactly the bytes the v1
        // whole-buffer encode produced, padding included
        let store = MemStore::new();
        let mut app = CounterApp::new(2, 9);
        for _ in 0..4 {
            app.step().unwrap();
        }
        for overhead in [false, true] {
            let seq = if overhead { 2 } else { 1 };
            checkpoint(&app, &store, "bytecmp", seq, overhead).unwrap();
            for i in 0..2 {
                let stored = store.get(&image_key("bytecmp", seq, i)).unwrap();
                let payload = app.serialize_proc(i).unwrap();
                let hdr = ImageHeader {
                    app: "bytecmp".into(),
                    proc_index: i,
                    ckpt_seq: seq,
                    kind: app.kind().to_string(),
                    iteration: app.iteration(),
                    payload_len: payload.len() as u64,
                };
                let expect = if overhead {
                    image::encode_with_runtime_overhead(&hdr, &payload)
                } else {
                    image::encode(&hdr, &payload)
                };
                assert_eq!(stored, expect, "overhead={overhead} proc={i}");
            }
        }
    }

    #[test]
    fn runtime_overhead_images_roundtrip() {
        let store = MemStore::new();
        let mut app = CounterApp::new(1, 64);
        app.step().unwrap();
        let report = checkpoint(&app, &store, "a", 1, true).unwrap();
        assert!(report.image_bytes[0] > image::RUNTIME_OVERHEAD_BYTES as u64);
        app.step().unwrap();
        restore(&mut app, &store, "a", None).unwrap();
        assert_eq!(app.iteration(), 1);
    }
}

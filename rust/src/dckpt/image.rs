//! Checkpoint image format + CRC-32.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic   4 B   "DCKP"
//! version 2 B
//! hlen    4 B   header JSON length
//! header  hlen  JSON: app, proc, seq, kind, iteration, payload_len
//! payload plen  raw process state
//! crc     4 B   CRC-32 (IEEE) of the payload
//! ```
//!
//! Real DMTCP images also carry the process's mapped libraries — that is
//! why the paper's Table 2 sizes behave like `data/n + c` with c ≈ 10 MB
//! rather than shrinking linearly to zero, and why the NS-3 cloudification
//! works on VMs with no NS-3 installed (§7.3.1: "the NS-3 libraries were
//! transported ... as part of the checkpoint images").  Serialization can
//! include that constant via `with_runtime_overhead`.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

pub const MAGIC: &[u8; 4] = b"DCKP";
pub const VERSION: u16 = 1;

/// Modelled size of the libraries/runtime a DMTCP image carries
/// (Table 2 fit: sizes ≈ 645 MB/n + ~10 MB).
pub const RUNTIME_OVERHEAD_BYTES: usize = 10 * 1024 * 1024;

/// Image metadata header.
#[derive(Debug, Clone, PartialEq)]
pub struct ImageHeader {
    pub app: String,
    pub proc_index: usize,
    pub ckpt_seq: u64,
    pub kind: String,
    pub iteration: u64,
    pub payload_len: u64,
}

impl ImageHeader {
    fn to_json(&self) -> Json {
        Json::object([
            ("app", self.app.as_str().into()),
            ("proc", self.proc_index.into()),
            ("seq", self.ckpt_seq.into()),
            ("kind", self.kind.as_str().into()),
            ("iteration", self.iteration.into()),
            ("payload_len", self.payload_len.into()),
        ])
    }

    fn from_json(j: &Json) -> Result<ImageHeader> {
        Ok(ImageHeader {
            app: j.get("app").as_str().context("header: app")?.to_string(),
            proc_index: j.get("proc").as_usize().context("header: proc")?,
            ckpt_seq: j.get("seq").as_u64().context("header: seq")?,
            kind: j.get("kind").as_str().context("header: kind")?.to_string(),
            iteration: j.get("iteration").as_u64().context("header: iteration")?,
            payload_len: j.get("payload_len").as_u64().context("header: payload_len")?,
        })
    }
}

/// CRC-32 (IEEE 802.3), slice-by-8 (§Perf iteration 1: the checkpoint
/// write path is CRC-dominated; slicing processes 8 bytes per step).
pub fn crc32(data: &[u8]) -> u32 {
    static TABLES: std::sync::OnceLock<[[u32; 256]; 8]> = std::sync::OnceLock::new();
    let tables = TABLES.get_or_init(|| {
        let mut t = [[0u32; 256]; 8];
        for i in 0..256usize {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            }
            t[0][i] = c;
        }
        for i in 0..256usize {
            let mut c = t[0][i];
            for k in 1..8 {
                c = t[0][(c & 0xFF) as usize] ^ (c >> 8);
                t[k][i] = c;
            }
        }
        t
    });
    let mut crc = 0xFFFFFFFFu32;
    let mut chunks = data.chunks_exact(8);
    for ch in &mut chunks {
        let lo = u32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]) ^ crc;
        let hi = u32::from_le_bytes([ch[4], ch[5], ch[6], ch[7]]);
        crc = tables[7][(lo & 0xFF) as usize]
            ^ tables[6][((lo >> 8) & 0xFF) as usize]
            ^ tables[5][((lo >> 16) & 0xFF) as usize]
            ^ tables[4][((lo >> 24) & 0xFF) as usize]
            ^ tables[3][(hi & 0xFF) as usize]
            ^ tables[2][((hi >> 8) & 0xFF) as usize]
            ^ tables[1][((hi >> 16) & 0xFF) as usize]
            ^ tables[0][((hi >> 24) & 0xFF) as usize];
    }
    for &b in chunks.remainder() {
        crc = tables[0][((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFFFFFF
}

/// Encode an image.
pub fn encode(header: &ImageHeader, payload: &[u8]) -> Vec<u8> {
    debug_assert_eq!(header.payload_len as usize, payload.len());
    let hjson = header.to_json().to_string().into_bytes();
    let mut out = Vec::with_capacity(4 + 2 + 4 + hjson.len() + payload.len() + 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(hjson.len() as u32).to_le_bytes());
    out.extend_from_slice(&hjson);
    out.extend_from_slice(payload);
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out
}

/// Encode with `RUNTIME_OVERHEAD_BYTES` of modelled library payload
/// appended (zeros; callers who care about wire size use this so image
/// sizes match the paper's `data/n + c` shape).
pub fn encode_with_runtime_overhead(header: &ImageHeader, payload: &[u8]) -> Vec<u8> {
    let mut padded = Vec::with_capacity(payload.len() + RUNTIME_OVERHEAD_BYTES);
    padded.extend_from_slice(payload);
    padded.resize(payload.len() + RUNTIME_OVERHEAD_BYTES, 0);
    let hdr = ImageHeader { payload_len: padded.len() as u64, ..header.clone() };
    encode(&hdr, &padded)
}

/// Decode and verify an image; returns (header, payload).
/// The runtime-overhead padding, if present, is the caller's to strip
/// (its length is `payload_len - original`; workloads know their sizes).
pub fn decode(data: &[u8]) -> Result<(ImageHeader, Vec<u8>)> {
    if data.len() < 14 {
        bail!("image truncated: {} bytes", data.len());
    }
    if &data[0..4] != MAGIC {
        bail!("bad magic");
    }
    let version = u16::from_le_bytes([data[4], data[5]]);
    if version != VERSION {
        bail!("unsupported image version {version}");
    }
    let hlen = u32::from_le_bytes([data[6], data[7], data[8], data[9]]) as usize;
    let hstart = 10;
    let hend = hstart + hlen;
    if data.len() < hend + 4 {
        bail!("image truncated in header");
    }
    let htext = std::str::from_utf8(&data[hstart..hend]).context("header utf-8")?;
    let header = ImageHeader::from_json(
        &crate::util::json::parse(htext).map_err(|e| anyhow::anyhow!("header json: {e}"))?,
    )?;
    let plen = header.payload_len as usize;
    let pend = hend + plen;
    if data.len() != pend + 4 {
        bail!(
            "image size mismatch: have {}, expected {}",
            data.len(),
            pend + 4
        );
    }
    let payload = data[hend..pend].to_vec();
    let want = u32::from_le_bytes([data[pend], data[pend + 1], data[pend + 2], data[pend + 3]]);
    let got = crc32(&payload);
    if want != got {
        bail!("payload crc mismatch: stored {want:#x}, computed {got:#x}");
    }
    Ok((header, payload))
}

/// Strip the runtime-overhead padding appended by
/// [`encode_with_runtime_overhead`].
pub fn strip_runtime_overhead(payload: &[u8]) -> &[u8] {
    if payload.len() >= RUNTIME_OVERHEAD_BYTES {
        &payload[..payload.len() - RUNTIME_OVERHEAD_BYTES]
    } else {
        payload
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hdr(plen: u64) -> ImageHeader {
        ImageHeader {
            app: "app-1".into(),
            proc_index: 2,
            ckpt_seq: 5,
            kind: "lu".into(),
            iteration: 100,
            payload_len: plen,
        }
    }

    #[test]
    fn crc32_known_vectors() {
        // standard test vector
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0x00000000);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414FA339);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let data = encode(&hdr(10_000), &payload);
        let (h, p) = decode(&data).unwrap();
        assert_eq!(h, hdr(10_000));
        assert_eq!(p, payload);
    }

    #[test]
    fn corruption_detected() {
        let payload = vec![7u8; 1000];
        let mut data = encode(&hdr(1000), &payload);
        // flip a payload byte
        let mid = data.len() - 500;
        data[mid] ^= 0x01;
        let err = decode(&data).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let payload = vec![1u8; 100];
        let data = encode(&hdr(100), &payload);
        assert!(decode(&data[..data.len() - 1]).is_err());
        assert!(decode(&data[..10]).is_err());
        assert!(decode(b"").is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let payload = vec![1u8; 10];
        let mut data = encode(&hdr(10), &payload);
        data[0] = b'X';
        assert!(decode(&data).unwrap_err().to_string().contains("magic"));
    }

    #[test]
    fn runtime_overhead_adds_constant() {
        let payload = vec![9u8; 1000];
        let data = encode_with_runtime_overhead(&hdr(1000), &payload);
        let (h, p) = decode(&data).unwrap();
        assert_eq!(h.payload_len as usize, 1000 + RUNTIME_OVERHEAD_BYTES);
        assert_eq!(strip_runtime_overhead(&p), &payload[..]);
        // wire size ≈ payload + overhead + small header
        assert!(data.len() > RUNTIME_OVERHEAD_BYTES + 1000);
        assert!(data.len() < RUNTIME_OVERHEAD_BYTES + 1000 + 512);
    }

    #[test]
    fn version_check() {
        let payload = vec![0u8; 4];
        let mut data = encode(&hdr(4), &payload);
        data[4] = 99;
        assert!(decode(&data).unwrap_err().to_string().contains("version"));
    }
}

//! Checkpoint image format + CRC-32.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic   4 B   "DCKP"
//! version 2 B   1 = full image, 2 = delta image
//! hlen    4 B   header JSON length
//! header  hlen  JSON: app, proc, seq, kind, iteration, payload_len
//!               (+ img, delta for v2 — see below)
//! payload plen  raw process state (v1) / dirty chunks only (v2)
//! crc     4 B   CRC-32 (IEEE) of the payload
//! ```
//!
//! # v2 delta images
//!
//! Version 2 keeps the wire framing above byte-for-byte and adds
//! **delta** images: the payload is only the chunks of the process
//! state that changed since a base cut, concatenated in ascending
//! chunk order, and the header JSON carries two extra fields —
//! `img: "delta"` plus a `delta` object ([`DeltaTable`]):
//!
//! ```text
//! delta: {
//!   base_seq:   u64   checkpoint sequence this delta is relative to
//!   base_len:   u64   raw payload length of the base the diff ran on
//!   full_len:   u64   reconstructed payload length
//!   chunk_size: u64   chunking granularity of the diff
//!   chunks:     [[chunk_index, payload_offset, len], ...]
//! }
//! ```
//!
//! Chain-resolution rules (implemented by [`crate::dckpt::service::restore`]):
//!
//! * Chains are **per process**: every delta image points at `base_seq`
//!   for the *same* proc index; a full image terminates the walk.
//! * Reconstruction walks back to the nearest full image, then replays
//!   the deltas forward: start from the base payload (stripped of its
//!   runtime-overhead padding when `base_len` says the diff ran on the
//!   raw state), resize to `full_len`, and overlay each chunk at
//!   `chunk_index × chunk_size`.
//! * Every chunk covers `chunk_size` bytes except possibly the final
//!   one; `len` must never exceed the space left in the reconstructed
//!   payload.
//! * Delta images never carry the runtime-overhead padding — the
//!   modelled DMTCP libraries are immutable, so only the full base
//!   image pays that constant.
//! * Writers bound chain length (`max_delta_chain`) by emitting a
//!   periodic full image; readers additionally cap the walk so a
//!   corrupt `base_seq` cycle cannot loop forever.
//!
//! Full images are still emitted as version 1 and stay byte-identical
//! to the original format (pinned by the golden-encoder property test).
//!
//! Real DMTCP images also carry the process's mapped libraries — that is
//! why the paper's Table 2 sizes behave like `data/n + c` with c ≈ 10 MB
//! rather than shrinking linearly to zero, and why the NS-3 cloudification
//! works on VMs with no NS-3 installed (§7.3.1: "the NS-3 libraries were
//! transported ... as part of the checkpoint images").  Serialization can
//! include that constant via `with_runtime_overhead`.
//!
//! # Streaming (§Perf iteration 2)
//!
//! The hot path is no longer "build the whole wire image in memory".
//! [`ImageWriter`] pushes the header and then payload *chunks* straight
//! into any [`std::io::Write`] sink (a store's streaming writer, a file,
//! a `Vec`), accumulating the CRC incrementally as bytes pass through;
//! [`ImageReader`]/[`decode_ref`] parse the structure and hand back a
//! *borrowed* payload slice after verifying the CRC in place.  Three
//! invariants keep it honest:
//!
//! * **Wire compatibility** — the bytes an [`ImageWriter`] emits are
//!   byte-identical to v1 [`encode`] output ([`encode`]/[`decode`] are
//!   now thin wrappers over the streaming core, so there is exactly one
//!   copy of the format logic).
//! * **Zero materialization** — the runtime-overhead padding is streamed
//!   from a static zero page and its CRC contribution is grafted in via
//!   [`crc32_combine`] (memoized for [`RUNTIME_OVERHEAD_BYTES`]), so the
//!   padding is never allocated, copied, or even re-hashed per image.
//! * **Chunk/shard equivalence** — the incremental [`Crc32`] hasher over
//!   any chunking, and parallel per-shard CRCs merged with
//!   [`crc32_combine`], produce exactly the one-shot [`crc32`] value
//!   (property-tested in `tests/props_substrates.rs`).  Large payloads
//!   are sharded across [`ThreadPool::shared`] workers.
//!
//! Perf iteration 1 made the CRC itself slice-by-8; iteration 2 removes
//! the two full-payload copies around it (wire-buffer build + decode
//! copy-out) and parallelizes the remaining CRC pass, so encode
//! throughput tracks memory bandwidth rather than single-core CRC speed.

use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, OnceLock};

pub const MAGIC: &[u8; 4] = b"DCKP";
/// Wire version of full images (unchanged since v1).
pub const VERSION: u16 = 1;
/// Wire version of delta images (same framing, delta header + payload).
pub const VERSION_DELTA: u16 = 2;

/// Modelled size of the libraries/runtime a DMTCP image carries
/// (Table 2 fit: sizes ≈ 645 MB/n + ~10 MB).
pub const RUNTIME_OVERHEAD_BYTES: usize = 10 * 1024 * 1024;

/// Payloads at or above this are CRC-hashed in parallel shards; below
/// it, shard dispatch overhead beats the win.
pub const PARALLEL_CRC_MIN_BYTES: usize = 4 * 1024 * 1024;

/// Static zero page streamed for runtime-overhead padding (never
/// allocate padding bytes per image).
const ZERO_PAGE_BYTES: usize = 64 * 1024;
static ZERO_PAGE: [u8; ZERO_PAGE_BYTES] = [0u8; ZERO_PAGE_BYTES];

/// One dirty chunk of a v2 delta image: which chunk of the
/// reconstructed payload it is, where its bytes sit in the delta
/// payload, and how many bytes it carries.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkRef {
    /// Chunk index in the reconstructed payload (`index × chunk_size`
    /// is the destination offset).
    pub index: u64,
    /// Byte offset of this chunk's data within the delta payload.
    pub offset: u64,
    /// Chunk length (`chunk_size` except possibly the final chunk).
    pub len: u64,
}

/// The v2 delta header extension: base pointer + chunk table.
#[derive(Debug, Clone, PartialEq)]
pub struct DeltaTable {
    /// Checkpoint sequence this delta is relative to.
    pub base_seq: u64,
    /// Raw payload length of the base the diff was computed against
    /// (without runtime-overhead padding).
    pub base_len: u64,
    /// Length of the reconstructed payload.
    pub full_len: u64,
    /// Chunking granularity of the diff.
    pub chunk_size: u64,
    /// Dirty chunks, ascending by index; offsets are contiguous.
    pub chunks: Vec<ChunkRef>,
}

impl DeltaTable {
    /// Total payload bytes the chunk table accounts for (must equal the
    /// image's `payload_len`).
    pub fn payload_bytes(&self) -> u64 {
        self.chunks.iter().map(|c| c.len).sum()
    }

    fn to_json(&self) -> Json {
        Json::object([
            ("base_seq", self.base_seq.into()),
            ("base_len", self.base_len.into()),
            ("full_len", self.full_len.into()),
            ("chunk_size", self.chunk_size.into()),
            (
                "chunks",
                Json::Arr(
                    self.chunks
                        .iter()
                        .map(|c| {
                            Json::Arr(vec![c.index.into(), c.offset.into(), c.len.into()])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    fn from_json(j: &Json) -> Result<DeltaTable> {
        let chunks = j
            .get("chunks")
            .as_arr()
            .context("delta: chunks")?
            .iter()
            .map(|c| {
                let arr = c.as_arr().context("delta: chunk entry")?;
                anyhow::ensure!(arr.len() == 3, "delta: chunk entry arity");
                Ok(ChunkRef {
                    index: arr[0].as_u64().context("delta: chunk index")?,
                    offset: arr[1].as_u64().context("delta: chunk offset")?,
                    len: arr[2].as_u64().context("delta: chunk len")?,
                })
            })
            .collect::<Result<Vec<ChunkRef>>>()?;
        Ok(DeltaTable {
            base_seq: j.get("base_seq").as_u64().context("delta: base_seq")?,
            base_len: j.get("base_len").as_u64().context("delta: base_len")?,
            full_len: j.get("full_len").as_u64().context("delta: full_len")?,
            chunk_size: j.get("chunk_size").as_u64().context("delta: chunk_size")?,
            chunks,
        })
    }
}

/// Image metadata header.
#[derive(Debug, Clone, PartialEq)]
pub struct ImageHeader {
    pub app: String,
    pub proc_index: usize,
    pub ckpt_seq: u64,
    pub kind: String,
    pub iteration: u64,
    pub payload_len: u64,
    /// Present on v2 delta images; `None` = full image.
    pub delta: Option<DeltaTable>,
}

impl ImageHeader {
    /// Whether this header describes a delta image.
    pub fn is_delta(&self) -> bool {
        self.delta.is_some()
    }

    fn to_json(&self) -> Json {
        let mut j = Json::object([
            ("app", self.app.as_str().into()),
            ("proc", self.proc_index.into()),
            ("seq", self.ckpt_seq.into()),
            ("kind", self.kind.as_str().into()),
            ("iteration", self.iteration.into()),
            ("payload_len", self.payload_len.into()),
        ]);
        // emitted only for deltas, so full images keep the exact v1
        // header bytes (pinned by the golden-encoder property test)
        if let Some(d) = &self.delta {
            j.set("img", "delta".into());
            j.set("delta", d.to_json());
        }
        j
    }

    fn from_json(j: &Json) -> Result<ImageHeader> {
        let delta = if j.get("delta").is_null() {
            None
        } else {
            Some(DeltaTable::from_json(j.get("delta"))?)
        };
        Ok(ImageHeader {
            app: j.get("app").as_str().context("header: app")?.to_string(),
            proc_index: j.get("proc").as_usize().context("header: proc")?,
            ckpt_seq: j.get("seq").as_u64().context("header: seq")?,
            kind: j.get("kind").as_str().context("header: kind")?.to_string(),
            iteration: j.get("iteration").as_u64().context("header: iteration")?,
            payload_len: j.get("payload_len").as_u64().context("header: payload_len")?,
            delta,
        })
    }
}

fn crc_tables() -> &'static [[u32; 256]; 8] {
    static TABLES: OnceLock<[[u32; 256]; 8]> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut t = [[0u32; 256]; 8];
        for i in 0..256usize {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            }
            t[0][i] = c;
        }
        for i in 0..256usize {
            let mut c = t[0][i];
            for k in 1..8 {
                c = t[0][(c & 0xFF) as usize] ^ (c >> 8);
                t[k][i] = c;
            }
        }
        t
    })
}

/// Advance a raw (pre/post-conditioning applied by the caller) CRC state
/// over `data`, slice-by-8.
fn crc32_advance(mut crc: u32, data: &[u8]) -> u32 {
    let tables = crc_tables();
    let mut chunks = data.chunks_exact(8);
    for ch in &mut chunks {
        let lo = u32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]) ^ crc;
        let hi = u32::from_le_bytes([ch[4], ch[5], ch[6], ch[7]]);
        crc = tables[7][(lo & 0xFF) as usize]
            ^ tables[6][((lo >> 8) & 0xFF) as usize]
            ^ tables[5][((lo >> 16) & 0xFF) as usize]
            ^ tables[4][((lo >> 24) & 0xFF) as usize]
            ^ tables[3][(hi & 0xFF) as usize]
            ^ tables[2][((hi >> 8) & 0xFF) as usize]
            ^ tables[1][((hi >> 16) & 0xFF) as usize]
            ^ tables[0][((hi >> 24) & 0xFF) as usize];
    }
    for &b in chunks.remainder() {
        crc = tables[0][((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc
}

/// CRC-32 (IEEE 802.3), slice-by-8 (§Perf iteration 1: the checkpoint
/// write path is CRC-dominated; slicing processes 8 bytes per step).
pub fn crc32(data: &[u8]) -> u32 {
    crc32_advance(0xFFFFFFFF, data) ^ 0xFFFFFFFF
}

/// Incremental CRC-32 hasher over the same slice-by-8 tables as
/// [`crc32`]: feeding any chunking of a buffer yields the one-shot value.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Crc32 {
        Crc32::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFFFFFF }
    }

    /// Absorb the next payload chunk.
    pub fn update(&mut self, data: &[u8]) {
        self.state = crc32_advance(self.state, data);
    }

    /// Absorb `n` zero bytes without materializing them — O(1) for the
    /// memoized [`RUNTIME_OVERHEAD_BYTES`] length, otherwise an O(n)
    /// hash over the static zero page, merged in with one combine.
    pub fn update_zeros(&mut self, n: usize) {
        self.combine(crc32_zeros(n), n as u64);
    }

    /// Append a chunk whose finalized CRC (`crc2` over `len2` bytes) was
    /// computed independently — the merge step of the parallel path.
    pub fn combine(&mut self, crc2: u32, len2: u64) {
        self.state = crc32_combine(self.finalize(), crc2, len2) ^ 0xFFFFFFFF;
    }

    /// The CRC of everything absorbed so far (does not consume; the
    /// hasher can keep absorbing).
    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFFFFFF
    }
}

fn gf2_matrix_times(mat: &[u32; 32], mut vec: u32) -> u32 {
    let mut sum = 0u32;
    let mut i = 0usize;
    while vec != 0 {
        if vec & 1 != 0 {
            sum ^= mat[i];
        }
        vec >>= 1;
        i += 1;
    }
    sum
}

fn gf2_matrix_square(square: &mut [u32; 32], mat: &[u32; 32]) {
    for n in 0..32 {
        square[n] = gf2_matrix_times(mat, mat[n]);
    }
}

/// Combine two independently computed CRCs: given `crc1 = crc32(A)` and
/// `crc2 = crc32(B)` with `len2 = B.len()`, returns `crc32(A ‖ B)` in
/// O(log len2) GF(2) matrix operations (zlib's `crc32_combine`).  This
/// is what lets large payloads be hashed in parallel shards.
pub fn crc32_combine(crc1: u32, crc2: u32, len2: u64) -> u32 {
    if len2 == 0 {
        return crc1;
    }
    let mut even = [0u32; 32]; // even-power-of-two zeros operator
    let mut odd = [0u32; 32]; // odd-power-of-two zeros operator

    // operator for one zero bit
    odd[0] = 0xEDB88320; // CRC-32 polynomial, reflected
    let mut row = 1u32;
    for n in 1..32 {
        odd[n] = row;
        row <<= 1;
    }
    gf2_matrix_square(&mut even, &odd); // two zero bits
    gf2_matrix_square(&mut odd, &even); // four zero bits

    // apply len2 zero *bytes* to crc1 (first square below yields the
    // eight-zero-bit = one-zero-byte operator)
    let mut crc1 = crc1;
    let mut len2 = len2;
    loop {
        gf2_matrix_square(&mut even, &odd);
        if len2 & 1 != 0 {
            crc1 = gf2_matrix_times(&even, crc1);
        }
        len2 >>= 1;
        if len2 == 0 {
            break;
        }
        gf2_matrix_square(&mut odd, &even);
        if len2 & 1 != 0 {
            crc1 = gf2_matrix_times(&odd, crc1);
        }
        len2 >>= 1;
    }
    crc1 ^ crc2
}

fn hash_zeros(n: usize) -> u32 {
    let mut state = 0xFFFFFFFFu32;
    let mut left = n;
    while left > 0 {
        let take = left.min(ZERO_PAGE_BYTES);
        state = crc32_advance(state, &ZERO_PAGE[..take]);
        left -= take;
    }
    state ^ 0xFFFFFFFF
}

/// CRC-32 of `n` zero bytes.  The [`RUNTIME_OVERHEAD_BYTES`] length is
/// memoized so every padded image after the first pays O(1) instead of
/// re-hashing 10 MB of zeros.
pub fn crc32_zeros(n: usize) -> u32 {
    if n == RUNTIME_OVERHEAD_BYTES {
        static OVERHEAD_CRC: OnceLock<u32> = OnceLock::new();
        *OVERHEAD_CRC.get_or_init(|| hash_zeros(RUNTIME_OVERHEAD_BYTES))
    } else {
        hash_zeros(n)
    }
}

/// CRC-32 of `data` computed in shards on `pool` and merged with
/// [`crc32_combine`]; falls back to serial below
/// [`PARALLEL_CRC_MIN_BYTES`] or when the pool has a single worker.
pub fn crc32_parallel(data: &[u8], pool: &ThreadPool) -> u32 {
    if data.len() < PARALLEL_CRC_MIN_BYTES || pool.size() < 2 {
        return crc32(data);
    }
    // at least 2 shards once past the threshold, one per ~4 MiB after
    let nshards = (data.len() / PARALLEL_CRC_MIN_BYTES).clamp(2, pool.size());
    let shard = (data.len() + nshards - 1) / nshards;
    let results: Arc<Vec<AtomicU32>> = Arc::new((0..nshards).map(|_| AtomicU32::new(0)).collect());
    let base = data.as_ptr() as usize;
    let items: Vec<(usize, usize, usize)> = (0..nshards)
        .map(|i| {
            let start = i * shard;
            (i, base + start, shard.min(data.len() - start))
        })
        .collect();
    let slot = results.clone();
    // SAFETY: `scatter` blocks until every job has run to completion, so
    // `data` strictly outlives the raw slices the workers reconstruct;
    // shards are disjoint and read-only.
    pool.scatter(items, move |(i, ptr, len)| {
        let bytes = unsafe { std::slice::from_raw_parts(ptr as *const u8, len) };
        slot[i].store(crc32(bytes), Ordering::Release);
    });
    let mut acc = Crc32::new();
    for (i, r) in results.iter().enumerate() {
        let len = shard.min(data.len() - i * shard);
        acc.combine(r.load(Ordering::Acquire), len as u64);
    }
    acc.finalize()
}

/// Push-based streaming encoder: emits the header up front, payload in
/// caller-sized chunks (CRC accumulated as bytes pass through), the CRC
/// trailer on [`finish`](ImageWriter::finish).  The wire bytes are
/// identical to [`encode`] for the same header/payload.
pub struct ImageWriter<W: Write> {
    out: W,
    crc: Crc32,
    declared: u64,
    written: u64,
    wire: u64,
}

impl<W: Write> ImageWriter<W> {
    /// Write magic/version/header for an image whose payload will be
    /// exactly `header.payload_len` streamed bytes.
    pub fn new(mut out: W, header: &ImageHeader) -> Result<ImageWriter<W>> {
        let hjson = header.to_json().to_string().into_bytes();
        let version = if header.is_delta() { VERSION_DELTA } else { VERSION };
        out.write_all(MAGIC)?;
        out.write_all(&version.to_le_bytes())?;
        out.write_all(&(hjson.len() as u32).to_le_bytes())?;
        out.write_all(&hjson)?;
        Ok(ImageWriter {
            out,
            crc: Crc32::new(),
            declared: header.payload_len,
            written: 0,
            wire: (10 + hjson.len()) as u64,
        })
    }

    /// Stream the next payload chunk, hashing it serially in-line.
    pub fn write_payload(&mut self, chunk: &[u8]) -> Result<()> {
        self.crc.update(chunk);
        self.out.write_all(chunk)?;
        self.written += chunk.len() as u64;
        self.wire += chunk.len() as u64;
        Ok(())
    }

    /// Stream a payload chunk whose CRC is computed in parallel shards
    /// on `pool` before the serial write; wire bytes are identical to
    /// [`write_payload`](ImageWriter::write_payload).
    pub fn write_payload_parallel(&mut self, chunk: &[u8], pool: &ThreadPool) -> Result<()> {
        self.crc.combine(crc32_parallel(chunk, pool), chunk.len() as u64);
        self.out.write_all(chunk)?;
        self.written += chunk.len() as u64;
        self.wire += chunk.len() as u64;
        Ok(())
    }

    /// Stream `n` zero bytes of payload (runtime-overhead padding) from
    /// the static zero page — the padding is never allocated, and its
    /// CRC contribution is a memoized O(1) combine for the common
    /// [`RUNTIME_OVERHEAD_BYTES`] length.
    pub fn write_zeros(&mut self, n: usize) -> Result<()> {
        let mut left = n;
        while left > 0 {
            let take = left.min(ZERO_PAGE_BYTES);
            self.out.write_all(&ZERO_PAGE[..take])?;
            left -= take;
        }
        self.crc.update_zeros(n);
        self.written += n as u64;
        self.wire += n as u64;
        Ok(())
    }

    /// Payload bytes streamed so far.
    pub fn payload_written(&self) -> u64 {
        self.written
    }

    /// Write the CRC trailer and return `(sink, total wire bytes)`.
    /// Fails if the streamed payload length differs from the declared
    /// `payload_len` (the header is already on the wire and cannot be
    /// amended).
    pub fn finish(mut self) -> Result<(W, u64)> {
        if self.written != self.declared {
            bail!(
                "image payload length mismatch: streamed {}, declared {}",
                self.written,
                self.declared
            );
        }
        self.out.write_all(&self.crc.finalize().to_le_bytes())?;
        Ok((self.out, self.wire + 4))
    }
}

/// Zero-copy view of an encoded image: [`new`](ImageReader::new) parses
/// and validates the structure (magic, version, header JSON, lengths)
/// without hashing; [`verify`](ImageReader::verify) checks the CRC over
/// the borrowed payload in place.
pub struct ImageReader<'a> {
    header: ImageHeader,
    payload: &'a [u8],
    stored_crc: u32,
}

impl<'a> ImageReader<'a> {
    pub fn new(data: &'a [u8]) -> Result<ImageReader<'a>> {
        if data.len() < 14 {
            bail!("image truncated: {} bytes", data.len());
        }
        if &data[0..4] != MAGIC {
            bail!("bad magic");
        }
        let version = u16::from_le_bytes([data[4], data[5]]);
        if version != VERSION && version != VERSION_DELTA {
            bail!("unsupported image version {version}");
        }
        let hlen = u32::from_le_bytes([data[6], data[7], data[8], data[9]]) as usize;
        let hstart = 10;
        let hend = hstart + hlen;
        if data.len() < hend + 4 {
            bail!("image truncated in header");
        }
        let htext = std::str::from_utf8(&data[hstart..hend]).context("header utf-8")?;
        let header = ImageHeader::from_json(
            &crate::util::json::parse(htext).map_err(|e| anyhow::anyhow!("header json: {e}"))?,
        )?;
        if header.is_delta() != (version == VERSION_DELTA) {
            bail!(
                "image version {version} disagrees with header delta={}",
                header.is_delta()
            );
        }
        let plen = header.payload_len as usize;
        let pend = hend + plen;
        if data.len() != pend + 4 {
            bail!(
                "image size mismatch: have {}, expected {}",
                data.len(),
                pend + 4
            );
        }
        let stored_crc =
            u32::from_le_bytes([data[pend], data[pend + 1], data[pend + 2], data[pend + 3]]);
        Ok(ImageReader { header, payload: &data[hend..pend], stored_crc })
    }

    pub fn header(&self) -> &ImageHeader {
        &self.header
    }

    /// The payload, borrowed from the encoded buffer (no copy).
    pub fn payload(&self) -> &'a [u8] {
        self.payload
    }

    pub fn stored_crc(&self) -> u32 {
        self.stored_crc
    }

    /// Verify the payload CRC serially.
    pub fn verify(&self) -> Result<()> {
        self.check(crc32(self.payload))
    }

    /// Verify the payload CRC in parallel shards on `pool`.
    pub fn verify_parallel(&self, pool: &ThreadPool) -> Result<()> {
        self.check(crc32_parallel(self.payload, pool))
    }

    /// Verify, sharding across [`ThreadPool::shared`] when the payload
    /// is large enough to benefit.
    pub fn verify_auto(&self) -> Result<()> {
        if self.payload.len() >= PARALLEL_CRC_MIN_BYTES {
            self.verify_parallel(ThreadPool::shared())
        } else {
            self.verify()
        }
    }

    fn check(&self, got: u32) -> Result<()> {
        let want = self.stored_crc;
        if want != got {
            bail!("payload crc mismatch: stored {want:#x}, computed {got:#x}");
        }
        Ok(())
    }
}

fn wire_capacity_hint(header: &ImageHeader) -> usize {
    // magic + version + hlen + (generous) header JSON + payload + crc
    4 + 2 + 4 + 256 + header.payload_len as usize + 4
}

/// Encode an image (thin wrapper over [`ImageWriter`] into a `Vec`).
pub fn encode(header: &ImageHeader, payload: &[u8]) -> Vec<u8> {
    debug_assert_eq!(header.payload_len as usize, payload.len());
    let mut w = ImageWriter::new(Vec::with_capacity(wire_capacity_hint(header)), header)
        .expect("Vec sink cannot fail");
    w.write_payload(payload).expect("Vec sink cannot fail");
    let (buf, _) = w.finish().expect("encode: payload length mismatch");
    buf
}

/// Encode with [`RUNTIME_OVERHEAD_BYTES`] of modelled library payload
/// appended (zeros; callers who care about wire size use this so image
/// sizes match the paper's `data/n + c` shape).  The padding is streamed
/// from the zero page, never materialized.
pub fn encode_with_runtime_overhead(header: &ImageHeader, payload: &[u8]) -> Vec<u8> {
    let hdr = ImageHeader {
        payload_len: (payload.len() + RUNTIME_OVERHEAD_BYTES) as u64,
        ..header.clone()
    };
    let mut w = ImageWriter::new(Vec::with_capacity(wire_capacity_hint(&hdr)), &hdr)
        .expect("Vec sink cannot fail");
    w.write_payload(payload).expect("Vec sink cannot fail");
    w.write_zeros(RUNTIME_OVERHEAD_BYTES).expect("Vec sink cannot fail");
    let (buf, _) = w.finish().expect("encode: payload length mismatch");
    buf
}

/// Decode and verify an image without copying: returns the header and a
/// payload slice borrowed from `data`.
pub fn decode_ref(data: &[u8]) -> Result<(ImageHeader, &[u8])> {
    let r = ImageReader::new(data)?;
    r.verify()?;
    let ImageReader { header, payload, .. } = r;
    Ok((header, payload))
}

/// Decode and verify an image; returns (header, payload).
/// The runtime-overhead padding, if present, is the caller's to strip
/// (its length is `payload_len - original`; workloads know their sizes).
pub fn decode(data: &[u8]) -> Result<(ImageHeader, Vec<u8>)> {
    let (header, payload) = decode_ref(data)?;
    Ok((header, payload.to_vec()))
}

/// Strip the runtime-overhead padding appended by
/// [`encode_with_runtime_overhead`].
pub fn strip_runtime_overhead(payload: &[u8]) -> &[u8] {
    if payload.len() >= RUNTIME_OVERHEAD_BYTES {
        &payload[..payload.len() - RUNTIME_OVERHEAD_BYTES]
    } else {
        payload
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hdr(plen: u64) -> ImageHeader {
        ImageHeader {
            app: "app-1".into(),
            proc_index: 2,
            ckpt_seq: 5,
            kind: "lu".into(),
            iteration: 100,
            payload_len: plen,
            delta: None,
        }
    }

    fn delta_hdr(plen: u64, chunks: Vec<ChunkRef>) -> ImageHeader {
        ImageHeader {
            delta: Some(DeltaTable {
                base_seq: 4,
                base_len: 1000,
                full_len: 1000,
                chunk_size: 64,
                chunks,
            }),
            ..hdr(plen)
        }
    }

    #[test]
    fn crc32_known_vectors() {
        // standard test vector
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0x00000000);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414FA339);
    }

    #[test]
    fn crc32_incremental_matches_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(70_001).collect();
        let mut h = Crc32::new();
        for chunk in data.chunks(777) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), crc32(&data));
    }

    #[test]
    fn crc32_combine_splits() {
        let data = b"123456789";
        for cut in 0..=data.len() {
            let (a, b) = data.split_at(cut);
            assert_eq!(crc32_combine(crc32(a), crc32(b), b.len() as u64), 0xCBF43926, "cut={cut}");
        }
        // len2 = 0 is the identity
        assert_eq!(crc32_combine(0xDEADBEEF, 0, 0), 0xDEADBEEF);
    }

    #[test]
    fn crc32_zeros_matches_hashing() {
        for n in [0usize, 1, 7, 4096, 100_000] {
            assert_eq!(crc32_zeros(n), crc32(&vec![0u8; n]), "n={n}");
        }
        let mut h = Crc32::new();
        h.update(b"prefix");
        h.update_zeros(12_345);
        let mut buf = b"prefix".to_vec();
        buf.resize(buf.len() + 12_345, 0);
        assert_eq!(h.finalize(), crc32(&buf));
    }

    #[test]
    fn crc32_parallel_matches_serial() {
        let pool = ThreadPool::new(4, 16);
        let data: Vec<u8> = (0..12 * 1024 * 1024usize).map(|i| (i * 31 % 251) as u8).collect();
        assert_eq!(crc32_parallel(&data, &pool), crc32(&data));
        // below the sharding threshold → serial fallback, same answer
        assert_eq!(crc32_parallel(&data[..1000], &pool), crc32(&data[..1000]));
        assert_eq!(crc32_parallel(&[], &pool), 0);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let data = encode(&hdr(10_000), &payload);
        let (h, p) = decode(&data).unwrap();
        assert_eq!(h, hdr(10_000));
        assert_eq!(p, payload);
    }

    #[test]
    fn decode_ref_borrows_payload() {
        let payload: Vec<u8> = (0..=255u8).cycle().take(5_000).collect();
        let data = encode(&hdr(5_000), &payload);
        let (h, p) = decode_ref(&data).unwrap();
        assert_eq!(h, hdr(5_000));
        assert_eq!(p, &payload[..]);
        // the slice really points into the encoded buffer
        let data_range = data.as_ptr() as usize..data.as_ptr() as usize + data.len();
        assert!(data_range.contains(&(p.as_ptr() as usize)));
    }

    #[test]
    fn streaming_writer_bytes_identical_to_encode() {
        let payload: Vec<u8> = (0..9_999usize).map(|i| (i % 256) as u8).collect();
        let whole = encode(&hdr(9_999), &payload);
        let mut w = ImageWriter::new(Vec::new(), &hdr(9_999)).unwrap();
        for chunk in payload.chunks(1_024) {
            w.write_payload(chunk).unwrap();
        }
        let (streamed, wire) = w.finish().unwrap();
        assert_eq!(streamed, whole);
        assert_eq!(wire as usize, whole.len());
    }

    #[test]
    fn streaming_writer_parallel_crc_identical() {
        let pool = ThreadPool::new(3, 8);
        let payload: Vec<u8> = (0..9 * 1024 * 1024usize).map(|i| (i * 17 % 253) as u8).collect();
        let h = hdr(payload.len() as u64);
        let whole = encode(&h, &payload);
        let mut w = ImageWriter::new(Vec::new(), &h).unwrap();
        w.write_payload_parallel(&payload, &pool).unwrap();
        let (streamed, _) = w.finish().unwrap();
        assert_eq!(streamed, whole);
    }

    #[test]
    fn streaming_writer_length_mismatch_rejected() {
        let mut w = ImageWriter::new(Vec::new(), &hdr(10)).unwrap();
        w.write_payload(&[1, 2, 3]).unwrap();
        let err = w.finish().unwrap_err().to_string();
        assert!(err.contains("length mismatch"), "{err}");
    }

    #[test]
    fn corruption_detected() {
        let payload = vec![7u8; 1000];
        let mut data = encode(&hdr(1000), &payload);
        // flip a payload byte
        let mid = data.len() - 500;
        data[mid] ^= 0x01;
        let err = decode(&data).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let payload = vec![1u8; 100];
        let data = encode(&hdr(100), &payload);
        assert!(decode(&data[..data.len() - 1]).is_err());
        assert!(decode(&data[..10]).is_err());
        assert!(decode(b"").is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let payload = vec![1u8; 10];
        let mut data = encode(&hdr(10), &payload);
        data[0] = b'X';
        assert!(decode(&data).unwrap_err().to_string().contains("magic"));
    }

    #[test]
    fn runtime_overhead_adds_constant() {
        let payload = vec![9u8; 1000];
        let data = encode_with_runtime_overhead(&hdr(1000), &payload);
        let (h, p) = decode(&data).unwrap();
        assert_eq!(h.payload_len as usize, 1000 + RUNTIME_OVERHEAD_BYTES);
        assert_eq!(strip_runtime_overhead(&p), &payload[..]);
        // wire size ≈ payload + overhead + small header
        assert!(data.len() > RUNTIME_OVERHEAD_BYTES + 1000);
        assert!(data.len() < RUNTIME_OVERHEAD_BYTES + 1000 + 512);
    }

    #[test]
    fn runtime_overhead_streaming_matches_materialized() {
        // golden: the v1 implementation materialized payload + zeros and
        // encoded that; the streaming path must emit identical bytes
        let payload: Vec<u8> = (0..3_000usize).map(|i| (i % 255) as u8).collect();
        let mut padded = payload.clone();
        padded.resize(payload.len() + RUNTIME_OVERHEAD_BYTES, 0);
        let full_hdr = hdr(padded.len() as u64);
        let golden = encode(&full_hdr, &padded);
        assert_eq!(encode_with_runtime_overhead(&hdr(3_000), &payload), golden);
    }

    #[test]
    fn version_check() {
        let payload = vec![0u8; 4];
        let mut data = encode(&hdr(4), &payload);
        data[4] = 99;
        assert!(decode(&data).unwrap_err().to_string().contains("version"));
    }

    #[test]
    fn delta_image_roundtrips_with_chunk_table() {
        let chunks = vec![
            ChunkRef { index: 1, offset: 0, len: 64 },
            ChunkRef { index: 7, offset: 64, len: 40 },
        ];
        let payload: Vec<u8> = (0..104u8).collect();
        let h = delta_hdr(104, chunks.clone());
        let data = encode(&h, &payload);
        // wire version is 2, framing unchanged
        assert_eq!(&data[0..4], MAGIC);
        assert_eq!(u16::from_le_bytes([data[4], data[5]]), VERSION_DELTA);
        let (back, p) = decode(&data).unwrap();
        assert_eq!(back, h);
        assert_eq!(p, payload);
        let d = back.delta.unwrap();
        assert_eq!(d.chunks, chunks);
        assert_eq!(d.payload_bytes(), 104);
    }

    #[test]
    fn delta_version_and_header_must_agree() {
        // a delta header wrapped in a v1 frame (or vice versa) is corrupt
        let payload: Vec<u8> = (0..104u8).collect();
        let h = delta_hdr(104, vec![ChunkRef { index: 0, offset: 0, len: 104 }]);
        let mut data = encode(&h, &payload);
        data[4] = 1; // claim v1 with a delta header
        assert!(decode(&data)
            .unwrap_err()
            .to_string()
            .contains("disagrees"));
        let mut data = encode(&hdr(4), &[0u8; 4]);
        data[4] = 2; // claim v2 with a full header
        assert!(decode(&data)
            .unwrap_err()
            .to_string()
            .contains("disagrees"));
    }

    #[test]
    fn full_images_stay_on_version_1() {
        let data = encode(&hdr(8), &[1u8; 8]);
        assert_eq!(u16::from_le_bytes([data[4], data[5]]), VERSION);
        // and their header JSON carries no delta keys
        let hlen = u32::from_le_bytes([data[6], data[7], data[8], data[9]]) as usize;
        let htext = std::str::from_utf8(&data[10..10 + hlen]).unwrap();
        assert!(!htext.contains("delta"), "{htext}");
    }
}

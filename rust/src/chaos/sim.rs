//! Chaos plan execution against the sim-mode CACS stack.
//!
//! [`run_plan`] builds a fresh two-cloud world (Snooze + OpenStack, a
//! Ceph back end, `n_apps` 2-VM LU applications with periodic
//! checkpoints and the Young/Daly adaptive controller on), warms it up
//! until every app is RUNNING with at least one acknowledged cut, then
//! installs the whole event schedule as DES events and lets it run to
//! `horizon + grace`.  The returned [`ChaosReport`] carries:
//!
//! * the invariant violations (empty on a healthy run): every acked
//!   checkpoint still on record, every app in RUNNING or TERMINATED;
//! * a FNV digest over the end state (lifecycles, checkpoint records,
//!   stamped timestamps, transfer counts) — two runs from the same seed
//!   must produce identical digests, which is how CI detects
//!   non-determinism sneaking into the models.
//!
//! Sim-mode mapping of the fault vocabulary: partitions, link flaps and
//! link degradation reshape NIC capacities in the fluid network (floored,
//! never zero, so stalled flows resume on heal) and make the monitor's
//! broadcast tree unreachable; spot revocations race a final cut
//! against the reclaim deadline, park the app SWAPPED_OUT with its VMs
//! released, and swap it back in once the park window passes — the
//! settle invariant therefore also proves no app is ever stranded in
//! the parked state, and the acked-cut invariant covers parked chains
//! because the revocation cut is acknowledged like any other; slow
//! stores scale the storage server links; failing/torn stores are a real-mode concern covered by
//! `storage::fault::FaultStore`.  After *any* capacity change the
//! network pump must be re-armed ([`simdrv::pump_net`]) because the
//! generation bump invalidates scheduled wake-ups.

use std::cell::RefCell;
use std::rc::Rc;

use crate::chaos::{ChaosConfig, ChaosEvent, ChaosKind};
use crate::coordinator::adaptive::AdaptiveCkptConfig;
use crate::coordinator::lifecycle::AppState;
use crate::coordinator::simdrv::{self, SimCacs, SimWorld};
use crate::coordinator::types::{Asr, WorkloadSpec};
use crate::netsim::LinkId;
use crate::simexec::Sim;
use crate::util::ids::AppId;
use crate::util::json::Json;

/// Virtual time spent getting every app to RUNNING with one acked cut
/// before injection starts.
pub const WARMUP_S: f64 = 1200.0;

/// Outcome of one chaos run.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub seed: u64,
    /// FNV-1a over the end state; equal across same-seed runs.
    pub digest: u64,
    pub end_time: f64,
    /// All coordinators ever created (initial apps + migration clones).
    pub apps_total: usize,
    pub apps_running: usize,
    pub apps_terminated: usize,
    /// Checkpoints acknowledged to the user (the `ckpt.uploads` counter).
    pub ckpts_acked: u64,
    /// Checkpoint records still held across all coordinators.
    pub ckpts_held: u64,
    pub violations: Vec<String>,
}

impl ChaosReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("seed", self.seed.into());
        j.set("digest", format!("{:016x}", self.digest).into());
        j.set("end_time_s", self.end_time.into());
        j.set("apps_total", self.apps_total.into());
        j.set("apps_running", self.apps_running.into());
        j.set("apps_terminated", self.apps_terminated.into());
        j.set("ckpts_acked", self.ckpts_acked.into());
        j.set("ckpts_held", self.ckpts_held.into());
        j.set("violations", self.violations.clone().into());
        j
    }
}

/// Execute `events` against a fresh seeded world; see module docs.
pub fn run_plan(cfg: &ChaosConfig, events: &[ChaosEvent]) -> ChaosReport {
    let mut violations: Vec<String> = vec![];
    let mut cacs = SimCacs::new(cfg.seed);
    cacs.world.params.adaptive =
        AdaptiveCkptConfig { enabled: true, min_period: 30.0, ..AdaptiveCkptConfig::default() };
    // chaos parks apps in ERROR far more often than production would;
    // the retry budget must outlive clustered outages
    cacs.world.params.max_recovery_retries = 100;
    let snooze = cacs.add_snooze(cfg.n_servers);
    let openstack = cacs.add_openstack(cfg.n_servers);
    let clouds = [snooze, openstack];

    let mut apps: Vec<AppId> = Vec::with_capacity(cfg.n_apps);
    for i in 0..cfg.n_apps {
        let asr = Asr::new(&format!("chaos-{i}"), WorkloadSpec::Lu { nz: 32, ny: 32, nx: 32 }, 2)
            .with_period(60.0);
        match cacs.submit(clouds[i % clouds.len()], asr) {
            Ok(id) => apps.push(id),
            Err(e) => violations.push(format!("submit {i} failed: {e}")),
        }
    }
    cacs.run_until(WARMUP_S);
    for &app in &apps {
        let rec = cacs.world.db.get(app);
        let state = rec.map(|r| r.lifecycle.state());
        if state != Some(AppState::Running) {
            violations.push(format!("warmup: {app} is {state:?}, not RUNNING"));
        }
        if rec.map(|r| r.ckpts.is_empty()).unwrap_or(true) {
            violations.push(format!("warmup: {app} has no acknowledged checkpoint"));
        }
    }

    // the registry follows migrations: when an app is migrated its slot
    // re-points at the clone, so later events keep hitting the live
    // incarnation instead of a terminated shell
    let registry = Rc::new(RefCell::new(apps));
    for ev in events {
        let kind = ev.kind;
        let reg = Rc::clone(&registry);
        cacs.sim.at(WARMUP_S + ev.at, move |sim, w| apply(sim, w, &reg, kind));
    }
    cacs.run_until(WARMUP_S + cfg.horizon + cfg.grace);
    finish(cfg, &cacs, violations)
}

fn apply(sim: &mut Sim<SimWorld>, w: &mut SimWorld, reg: &Rc<RefCell<Vec<AppId>>>, kind: ChaosKind) {
    match kind {
        ChaosKind::AppCrash { app } => {
            let id = reg.borrow()[app];
            simdrv::app_failure_now(w, id);
        }
        ChaosKind::VmCrash { app } => {
            let id = reg.borrow()[app];
            simdrv::vm_failure_now(sim, w, id);
        }
        ChaosKind::Partition { app, for_s } => {
            let id = reg.borrow()[app];
            partition(sim, w, id, for_s);
        }
        ChaosKind::DegradeLink { app, factor, for_s } => {
            let id = reg.borrow()[app];
            scale_nics(sim, w, id, factor, for_s);
        }
        ChaosKind::LinkFlap { app, flaps, down_s, up_s } => {
            let id = reg.borrow()[app];
            link_flap(sim, w, id, flaps, down_s, up_s);
        }
        ChaosKind::SlowStore { factor, for_s } => slow_store(sim, w, factor, for_s),
        ChaosKind::ClockSkew { cloud, skew_s } => {
            if let Some(s) = w.clock_skew.get_mut(cloud) {
                *s = skew_s;
            }
        }
        ChaosKind::Checkpoint { app } => {
            let id = reg.borrow()[app];
            simdrv::start_checkpoint(sim, w, id);
        }
        ChaosKind::Restart { app } => {
            let id = reg.borrow()[app];
            simdrv::start_restart(sim, w, id);
        }
        ChaosKind::Migrate { app, to_cloud } => {
            let id = reg.borrow()[app];
            if let Ok(clone) = simdrv::migrate_now(sim, w, id, to_cloud) {
                reg.borrow_mut()[app] = clone;
            }
        }
        ChaosKind::Terminate { app } => {
            let id = reg.borrow()[app];
            simdrv::terminate(sim, w, id);
        }
        ChaosKind::SpotRevocation { app, deadline_s, park_s } => {
            let id = reg.borrow()[app];
            simdrv::spot_revocation_now(sim, w, id, deadline_s);
            // capacity returns park_s after the reclaim deadline: the
            // harness swaps the app back in (a no-op unless this very
            // revocation parked it, so every park has a pending resume
            // and no app can end the run SWAPPED_OUT)
            sim.after(deadline_s + park_s, move |sim, w| simdrv::swap_in_now(sim, w, id));
        }
        ChaosKind::CrashDuringCheckpoint { app, after_s } => {
            let id = reg.borrow()[app];
            simdrv::start_checkpoint(sim, w, id);
            sim.after(after_s, move |_sim, w| simdrv::app_failure_now(w, id));
        }
        ChaosKind::CrashDuringRestore { app, after_s } => {
            let id = reg.borrow()[app];
            simdrv::start_restart(sim, w, id);
            sim.after(after_s, move |sim, w| simdrv::vm_failure_now(sim, w, id));
        }
        ChaosKind::CrashDuringMigration { app, to_cloud, after_s } => {
            let id = reg.borrow()[app];
            if let Ok(clone) = simdrv::migrate_now(sim, w, id, to_cloud) {
                reg.borrow_mut()[app] = clone;
                // kill the *source* mid-transfer; the clone must still
                // come up from the shared images
                sim.after(after_s, move |sim, w| simdrv::vm_failure_now(sim, w, id));
            }
        }
    }
}

/// Cut the app's NICs to the capacity floor and mark the monitor's
/// broadcast tree unreachable for `for_s` seconds (split-brain), then
/// heal.  Capacities are floored, never zeroed, so flows stalled by the
/// partition resume on heal.
fn partition(sim: &mut Sim<SimWorld>, w: &mut SimWorld, app: AppId, for_s: f64) {
    let now = sim.now();
    if let Some(e) = w.ext.get_mut(&app) {
        e.partitioned_until = e.partitioned_until.max(now + for_s);
    }
    let saved = set_nic_caps(w, now, app, |_| 0.0);
    simdrv::pump_net(sim, w);
    sim.after(for_s, move |sim, w| heal(sim, w, saved));
}

/// Lossy WAN link: `flaps` cycles of a `down_s`-second outage (NICs cut
/// to the capacity floor, like a partition — every in-flight transfer
/// stalls) followed by `up_s` seconds of healthy link.  Stalled flows
/// resume on each heal, so an app mid-transfer rides the flaps out.
fn link_flap(
    sim: &mut Sim<SimWorld>,
    w: &mut SimWorld,
    app: AppId,
    flaps: usize,
    down_s: f64,
    up_s: f64,
) {
    if flaps == 0 {
        return;
    }
    let now = sim.now();
    let saved = set_nic_caps(w, now, app, |_| 0.0);
    simdrv::pump_net(sim, w);
    sim.after(down_s, move |sim, w| {
        heal(sim, w, saved);
        if flaps > 1 {
            sim.after(up_s, move |sim, w| link_flap(sim, w, app, flaps - 1, down_s, up_s));
        }
    });
}

/// Scale the app's NIC capacities by `factor` for `for_s` seconds.
fn scale_nics(sim: &mut Sim<SimWorld>, w: &mut SimWorld, app: AppId, factor: f64, for_s: f64) {
    let now = sim.now();
    let saved = set_nic_caps(w, now, app, |cur| cur * factor);
    simdrv::pump_net(sim, w);
    sim.after(for_s, move |sim, w| heal(sim, w, saved));
}

/// Scale the storage back end's server links by `factor` (the sim-mode
/// slow-store fault) for `for_s` seconds.
fn slow_store(sim: &mut Sim<SimWorld>, w: &mut SimWorld, factor: f64, for_s: f64) {
    let now = sim.now();
    let links = w.storage.server_links.clone();
    let mut saved = Vec::with_capacity(links.len());
    for link in links {
        let cur = w.net.link_capacity(link);
        let prev = w.net.set_link_capacity(now, link, cur * factor);
        saved.push((link, prev));
    }
    simdrv::pump_net(sim, w);
    sim.after(for_s, move |sim, w| heal(sim, w, saved));
}

fn set_nic_caps(
    w: &mut SimWorld,
    now: f64,
    app: AppId,
    new_cap: impl Fn(f64) -> f64,
) -> Vec<(LinkId, f64)> {
    let Some(rec) = w.db.get(app) else { return vec![] };
    let cloud_idx = rec.cloud_idx;
    let vms = rec.vms.clone();
    let mut saved = Vec::with_capacity(vms.len());
    for vm in vms {
        let nic = match w.clouds[cloud_idx].vm_record(vm) {
            Some(r) => r.nic,
            None => continue,
        };
        let cur = w.net.link_capacity(nic);
        let prev = w.net.set_link_capacity(now, nic, new_cap(cur));
        saved.push((nic, prev));
    }
    saved
}

/// Restore saved capacities (in reverse, to unwind duplicates sanely)
/// and re-arm the pump off the reshaped completion schedule.
fn heal(sim: &mut Sim<SimWorld>, w: &mut SimWorld, saved: Vec<(LinkId, f64)>) {
    let now = sim.now();
    for (link, prev) in saved.into_iter().rev() {
        w.net.set_link_capacity(now, link, prev);
    }
    simdrv::pump_net(sim, w);
}

fn finish(cfg: &ChaosConfig, cacs: &SimCacs, mut violations: Vec<String>) -> ChaosReport {
    let w = &cacs.world;
    let mut running = 0usize;
    let mut terminated = 0usize;
    for rec in w.db.iter() {
        match rec.lifecycle.state() {
            AppState::Running => running += 1,
            AppState::Terminated => terminated += 1,
            s => violations.push(format!("{} ended {s}, not RUNNING/TERMINATED", rec.id)),
        }
    }
    let acked = w.rec.counter("ckpt.uploads") as u64;
    let held: u64 = w.db.iter().map(|r| r.ckpts.len() as u64).sum();
    if held != acked {
        violations.push(format!(
            "acknowledged checkpoints lost: {acked} acked, {held} on record"
        ));
    }
    ChaosReport {
        seed: cfg.seed,
        digest: digest(cacs),
        end_time: cacs.sim.now(),
        apps_total: w.db.len(),
        apps_running: running,
        apps_terminated: terminated,
        ckpts_acked: acked,
        ckpts_held: held,
        violations,
    }
}

struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    fn mix(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
}

/// FNV-1a over everything observable about the end state.  Two runs
/// from the same seed over the same plan must agree bit-for-bit.
pub fn digest(cacs: &SimCacs) -> u64 {
    let w = &cacs.world;
    let mut h = Fnv::new();
    h.mix(cacs.sim.now().to_bits());
    h.mix(w.db.len() as u64);
    for rec in w.db.iter() {
        h.mix(rec.id.0);
        h.mix(rec.lifecycle.state() as u64);
        h.mix(rec.vms.len() as u64);
        h.mix(rec.ckpts.len() as u64);
        for ck in &rec.ckpts {
            h.mix(ck.seq);
            h.mix(ck.taken_at.to_bits());
            h.mix(ck.total_bytes);
        }
        if let Some(e) = w.ext.get(&rec.id) {
            h.mix(e.heartbeats.len() as u64);
            h.mix(e.ckpt_timings.len() as u64);
            h.mix(e.restart_timings.len() as u64);
        }
    }
    h.mix(w.rec.counter("ckpt.uploads").to_bits());
    h.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::plan;

    #[test]
    fn same_seed_same_digest() {
        let cfg = ChaosConfig::sized(0xCAC5, 60);
        let evs = plan(&cfg, 60);
        let a = run_plan(&cfg, &evs);
        let b = run_plan(&cfg, &evs);
        assert!(a.ok(), "seed {} violations: {:?}", a.seed, a.violations);
        assert_eq!(a.digest, b.digest, "same seed must be bit-reproducible");
        assert_eq!(a.end_time, b.end_time);
    }

    #[test]
    fn different_seeds_diverge() {
        let c1 = ChaosConfig::sized(100, 40);
        let c2 = ChaosConfig::sized(101, 40);
        let a = run_plan(&c1, &plan(&c1, 40));
        let b = run_plan(&c2, &plan(&c2, 40));
        assert_ne!(a.digest, b.digest);
    }

    #[test]
    fn acceptance_no_lost_cuts_every_app_settles() {
        // a scaled-down version of the 1000-event CI acceptance run
        let cfg = ChaosConfig::sized(1, 150);
        let evs = plan(&cfg, 150);
        let r = run_plan(&cfg, &evs);
        assert!(r.ok(), "seed {} violations: {:?}", r.seed, r.violations);
        assert_eq!(r.ckpts_held, r.ckpts_acked, "acked cuts must survive");
        assert_eq!(r.apps_running + r.apps_terminated, r.apps_total);
        assert!(r.ckpts_acked > 20, "chaos run should keep checkpointing: {}", r.ckpts_acked);
    }

    #[test]
    fn partition_splits_the_brain_then_heals() {
        // one 30 s partition: the monitor must lose the broadcast tree,
        // spuriously recover the app (split-brain), and end RUNNING
        let cfg = ChaosConfig::sized(3, 0);
        let evs =
            vec![ChaosEvent { at: 10.0, kind: ChaosKind::Partition { app: 0, for_s: 30.0 } }];
        let r = run_plan(&cfg, &evs);
        assert!(r.ok(), "violations: {:?}", r.violations);
    }

    #[test]
    fn crash_points_recover_mid_protocol() {
        let cfg = ChaosConfig::sized(8, 0);
        let evs = vec![
            ChaosEvent { at: 5.0, kind: ChaosKind::CrashDuringCheckpoint { app: 0, after_s: 0.5 } },
            ChaosEvent { at: 60.0, kind: ChaosKind::CrashDuringRestore { app: 1, after_s: 1.0 } },
            ChaosEvent {
                at: 120.0,
                kind: ChaosKind::CrashDuringMigration { app: 2, to_cloud: 1, after_s: 2.0 },
            },
        ];
        let r = run_plan(&cfg, &evs);
        assert!(r.ok(), "violations: {:?}", r.violations);
        // the migrated slot ended as a clone beyond the initial set
        assert!(r.apps_total > cfg.n_apps, "migration should have cloned");
        assert!(r.apps_terminated >= 1, "migration source should be torn down");
    }

    #[test]
    fn link_flaps_kill_transfers_but_the_run_settles() {
        // three outage/heal cycles thrown right on top of a checkpoint:
        // each flap stalls the in-flight upload, each heal resumes it,
        // and the acked-cut invariant must hold at the end
        let cfg = ChaosConfig::sized(17, 0);
        let evs = vec![
            ChaosEvent { at: 5.0, kind: ChaosKind::Checkpoint { app: 0 } },
            ChaosEvent {
                at: 6.0,
                kind: ChaosKind::LinkFlap { app: 0, flaps: 3, down_s: 8.0, up_s: 10.0 },
            },
        ];
        let a = run_plan(&cfg, &evs);
        assert!(a.ok(), "violations: {:?}", a.violations);
        assert_eq!(a.ckpts_held, a.ckpts_acked, "no acked cut may be lost to a flap");
        let b = run_plan(&cfg, &evs);
        assert_eq!(a.digest, b.digest, "flap scheduling must stay deterministic");
    }

    #[test]
    fn spot_revocation_parks_then_resumes() {
        // one revocation with a generous deadline: the final cut lands,
        // the app parks SWAPPED_OUT, and the scheduled swap-in must
        // return it to RUNNING inside the grace window — with the
        // revocation cut still on record (acked-cut invariant over the
        // parked chain)
        let cfg = ChaosConfig::sized(33, 0);
        let evs = vec![ChaosEvent {
            at: 10.0,
            kind: ChaosKind::SpotRevocation { app: 0, deadline_s: 60.0, park_s: 120.0 },
        }];
        let r = run_plan(&cfg, &evs);
        assert!(r.ok(), "violations: {:?}", r.violations);
        assert_eq!(r.ckpts_held, r.ckpts_acked, "parked chain must stay acknowledged");
    }

    #[test]
    fn spot_revocation_that_loses_the_race_still_settles() {
        // a deadline no cut can meet: the VMs are reclaimed mid-cut and
        // ordinary §6.3 recovery restores from the previous image
        let cfg = ChaosConfig::sized(34, 0);
        let evs = vec![ChaosEvent {
            at: 10.0,
            kind: ChaosKind::SpotRevocation { app: 0, deadline_s: 1e-6, park_s: 60.0 },
        }];
        let r = run_plan(&cfg, &evs);
        assert!(r.ok(), "violations: {:?}", r.violations);
    }

    #[test]
    fn clock_skew_never_changes_behaviour_only_stamps() {
        let cfg = ChaosConfig::sized(21, 0);
        let base = run_plan(&cfg, &[]);
        let skewed = run_plan(
            &cfg,
            &[ChaosEvent { at: 1.0, kind: ChaosKind::ClockSkew { cloud: 0, skew_s: 240.0 } }],
        );
        assert!(base.ok() && skewed.ok());
        // same number of cuts acked either way — skew shifts stamped
        // metadata (which the digest sees) but never event order
        assert_eq!(base.ckpts_acked, skewed.ckpts_acked);
        assert_ne!(base.digest, skewed.digest, "skewed stamps must show in the digest");
    }
}

//! Deterministic, seeded chaos harness over the sim-mode CACS stack.
//!
//! Everything the harness does is reproducible from one `u64` seed: the
//! seed fixes the injected event plan ([`plan`]), the world it runs
//! against, and every model sample drawn while the run unfolds, so a
//! failing run reported by CI can be replayed bit-for-bit from the
//! printed seed alone.  The pieces:
//!
//! * [`ChaosKind`] / [`ChaosEvent`] — the injectable event vocabulary:
//!   network partitions that split an app's monitor broadcast tree
//!   (split-brain), asymmetric link degradation, slow storage back
//!   ends, clock skew between CACS instances, straight app/VM crashes,
//!   spot-revocation warnings that race a final cut against a reclaim
//!   deadline and park the app SWAPPED_OUT (§2.2 use case 4), and
//!   crash points parked inside every multi-step protocol (checkpoint,
//!   delta-chain restore, migration);
//! * [`plan`] — seeded, weighted generation of an event schedule;
//! * [`sim::run_plan`] — executes a schedule against a freshly built
//!   two-cloud world and returns a [`sim::ChaosReport`] carrying the
//!   invariant violations (if any) and a run digest for
//!   bit-reproducibility checks;
//! * [`shrink`] — ddmin-style minimisation of a failing event log: CI
//!   prints the seed plus the minimal sub-schedule that still trips the
//!   invariant.
//!
//! The invariants every run is held to: no acknowledged checkpoint is
//! ever lost, and after the grace window every application sits in
//! RUNNING or cleanly TERMINATED — never wedged half way through a
//! protocol.

pub mod sim;

use crate::util::rng::Rng;

/// One injectable fault or action.  `app` fields index the harness's
/// app registry (migrations re-point an index at the clone), `cloud`
/// fields index the two harness clouds (0 = Snooze, 1 = OpenStack).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChaosKind {
    /// §6.3 case 2: the health hook fails while VMs stay reachable.
    AppCrash { app: usize },
    /// §6.3 case 1: the server under the app's first VM dies.
    VmCrash { app: usize },
    /// Split-brain: the app's NICs are cut off and the monitor loses
    /// the whole broadcast tree for `for_s` seconds while the app
    /// itself keeps computing on the far side.
    Partition { app: usize, for_s: f64 },
    /// Asymmetric degradation: the app's NIC capacities are scaled by
    /// `factor` for `for_s` seconds.
    DegradeLink { app: usize, factor: f64, for_s: f64 },
    /// Lossy WAN link: the app's NICs flap — `flaps` cycles of a
    /// `down_s`-second near-total outage followed by `up_s` seconds of
    /// healthy link.  Each outage kills whatever transfer is in flight,
    /// which is exactly what pull-mode migration's resumable range
    /// fetches are built to survive.
    LinkFlap { app: usize, flaps: usize, down_s: f64, up_s: f64 },
    /// The storage back end's server links slow down by `factor`.
    SlowStore { factor: f64, for_s: f64 },
    /// One cloud's CACS instance drifts `skew_s` seconds off true time
    /// (shows up in stamped metadata, never in event order).
    ClockSkew { cloud: usize, skew_s: f64 },
    /// User-triggered checkpoint (§5.2 mode 1).
    Checkpoint { app: usize },
    /// Restart from the latest image (§5.3).
    Restart { app: usize },
    /// Cross-cloud migration (§5.3); the registry follows the clone.
    Migrate { app: usize, to_cloud: usize },
    /// DELETE /coordinators/:id (§5.4).
    Terminate { app: usize },
    /// §2.2 use case 4: a spot-revocation warning.  CACS races a final
    /// cut against the `deadline_s` reclaim deadline; a cut that lands
    /// parks the app SWAPPED_OUT with its VMs released, and the harness
    /// swaps it back in `park_s` seconds after the deadline.
    SpotRevocation { app: usize, deadline_s: f64, park_s: f64 },
    /// Crash point: start a checkpoint, then fail the app `after_s`
    /// seconds in — mid local cut or mid upload.
    CrashDuringCheckpoint { app: usize, after_s: f64 },
    /// Crash point: start a restore, then kill a VM `after_s` seconds
    /// in — mid download or mid local restart.
    CrashDuringRestore { app: usize, after_s: f64 },
    /// Crash point: start a migration, then kill a source VM while the
    /// clone is still building/restoring.
    CrashDuringMigration { app: usize, to_cloud: usize, after_s: f64 },
}

/// An event at a virtual-time offset from the end of warmup.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosEvent {
    pub at: f64,
    pub kind: ChaosKind,
}

/// Harness shape: world size and schedule window.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// The one seed everything derives from.
    pub seed: u64,
    /// Applications submitted during warmup (half per cloud).
    pub n_apps: usize,
    /// Servers per cloud — sized so the run survives every VM crash in
    /// the plan (a killed server never comes back).
    pub n_servers: usize,
    /// Injection window (s) after warmup over which events spread.
    pub horizon: f64,
    /// Drain window (s) after the last event: every in-flight recovery,
    /// retry back-off and heal must settle inside it.
    pub grace: f64,
}

impl ChaosConfig {
    /// A config sized for an `n_events`-event run.
    pub fn sized(seed: u64, n_events: usize) -> ChaosConfig {
        ChaosConfig {
            seed,
            n_apps: 6,
            // ~15% of events kill a server for good; keep enough spares
            n_servers: (n_events / 8).max(96),
            horizon: (n_events as f64 * 4.0).max(600.0),
            grace: 2400.0,
        }
    }
}

/// Generate a seeded, weighted event schedule: crashes and protocol
/// crash points ~30%, connectivity/storage/clock disturbance ~31%,
/// normal driver actions (checkpoint/restart/migrate/terminate) the
/// rest.  Terminations are capped so the run keeps enough live apps to
/// stay interesting.  Deterministic: same config, same plan.
pub fn plan(cfg: &ChaosConfig, n_events: usize) -> Vec<ChaosEvent> {
    let mut rng = Rng::new(cfg.seed ^ 0x5eed_c4a0_5eed_c4a0);
    let mut terminates_left = (cfg.n_apps / 4).max(1);
    let mut evs = Vec::with_capacity(n_events);
    for _ in 0..n_events {
        let at = rng.uniform(0.0, cfg.horizon);
        // drawn even for kinds that ignore it, to keep the stream stable
        let app = rng.pick(cfg.n_apps);
        let roll = rng.f64();
        let kind = if roll < 0.10 {
            ChaosKind::AppCrash { app }
        } else if roll < 0.15 {
            ChaosKind::VmCrash { app }
        } else if roll < 0.23 {
            ChaosKind::Partition { app, for_s: rng.uniform(10.0, 60.0) }
        } else if roll < 0.33 {
            ChaosKind::DegradeLink {
                app,
                factor: rng.uniform(0.05, 0.5),
                for_s: rng.uniform(20.0, 120.0),
            }
        } else if roll < 0.41 {
            ChaosKind::SlowStore { factor: rng.uniform(0.1, 0.5), for_s: rng.uniform(20.0, 120.0) }
        } else if roll < 0.46 {
            ChaosKind::ClockSkew { cloud: rng.pick(2), skew_s: rng.uniform(-300.0, 300.0) }
        } else if roll < 0.62 {
            ChaosKind::Checkpoint { app }
        } else if roll < 0.66 {
            // carved from the checkpoint band; like SpotRevocation below,
            // parameters derive from the roll itself so older seeded
            // plans keep every other event exactly where it was
            let frac = (roll - 0.62) / 0.04;
            ChaosKind::LinkFlap {
                app,
                flaps: 1 + (frac * 3.0) as usize,
                down_s: 2.0 + 10.0 * frac,
                up_s: 5.0 + 20.0 * (1.0 - frac),
            }
        } else if roll < 0.71 {
            // parameters derive from the roll itself (uniform within
            // the band) instead of fresh draws, so every other event in
            // a seeded plan sits exactly where it did before this
            // variant was carved out of the checkpoint band
            let frac = (roll - 0.66) / 0.05;
            ChaosKind::SpotRevocation {
                app,
                deadline_s: 5.0 + 55.0 * frac,
                park_s: 30.0 + 270.0 * (1.0 - frac),
            }
        } else if roll < 0.79 {
            ChaosKind::Restart { app }
        } else if roll < 0.83 {
            ChaosKind::Migrate { app, to_cloud: rng.pick(2) }
        } else if roll < 0.88 {
            ChaosKind::CrashDuringCheckpoint { app, after_s: rng.uniform(0.05, 2.0) }
        } else if roll < 0.93 {
            ChaosKind::CrashDuringRestore { app, after_s: rng.uniform(0.05, 2.0) }
        } else if roll < 0.98 {
            ChaosKind::CrashDuringMigration {
                app,
                to_cloud: rng.pick(2),
                after_s: rng.uniform(0.5, 5.0),
            }
        } else if terminates_left > 0 {
            terminates_left -= 1;
            ChaosKind::Terminate { app }
        } else {
            ChaosKind::Checkpoint { app }
        };
        evs.push(ChaosEvent { at, kind });
    }
    evs.sort_by(|a, b| a.at.total_cmp(&b.at));
    evs
}

/// ddmin-style shrink: given a failing event log and a predicate that
/// re-runs a candidate sub-log and answers "does it still fail?",
/// return a (locally) minimal sub-log that still trips the failure.
/// Each candidate keeps the original relative order, so the minimal log
/// replays against the same seed.
pub fn shrink<F>(events: &[ChaosEvent], still_fails: F) -> Vec<ChaosEvent>
where
    F: Fn(&[ChaosEvent]) -> bool,
{
    let mut cur = events.to_vec();
    let mut n = 2usize;
    while cur.len() >= 2 {
        let chunk = cur.len().div_ceil(n);
        let mut reduced = false;
        let mut i = 0;
        while i < cur.len() {
            let mut candidate = cur.clone();
            let end = (i + chunk).min(candidate.len());
            candidate.drain(i..end);
            if !candidate.is_empty() && still_fails(&candidate) {
                cur = candidate;
                n = n.saturating_sub(1).max(2);
                reduced = true;
                // re-scan from the front at the smaller size
                i = 0;
            } else {
                i += chunk;
            }
        }
        if !reduced {
            if n >= cur.len() {
                break;
            }
            n = (n * 2).min(cur.len());
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_is_deterministic_in_the_seed() {
        let cfg = ChaosConfig::sized(42, 200);
        let a = plan(&cfg, 200);
        let b = plan(&cfg, 200);
        assert_eq!(a, b);
        let other = plan(&ChaosConfig::sized(43, 200), 200);
        assert_ne!(a, other, "different seeds must give different plans");
    }

    #[test]
    fn plan_is_sorted_and_in_window() {
        let cfg = ChaosConfig::sized(7, 500);
        let evs = plan(&cfg, 500);
        assert_eq!(evs.len(), 500);
        for w in evs.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert!(evs.iter().all(|e| e.at >= 0.0 && e.at < cfg.horizon));
    }

    #[test]
    fn plan_caps_terminations() {
        let cfg = ChaosConfig::sized(11, 2000);
        let evs = plan(&cfg, 2000);
        let terms = evs
            .iter()
            .filter(|e| matches!(e.kind, ChaosKind::Terminate { .. }))
            .count();
        assert!(terms <= (cfg.n_apps / 4).max(1), "terms={terms}");
    }

    #[test]
    fn plan_carves_link_flaps_with_roll_derived_parameters() {
        let cfg = ChaosConfig::sized(13, 2000);
        let evs = plan(&cfg, 2000);
        let flaps: Vec<_> = evs
            .iter()
            .filter_map(|e| match e.kind {
                ChaosKind::LinkFlap { flaps, down_s, up_s, .. } => Some((flaps, down_s, up_s)),
                _ => None,
            })
            .collect();
        // the band is 4% wide: a 2000-event plan all but surely hits it
        assert!(!flaps.is_empty(), "no LinkFlap in a 2000-event plan");
        for (n, down_s, up_s) in flaps {
            assert!((1..=4).contains(&n), "flaps={n}");
            assert!((2.0..12.0).contains(&down_s), "down_s={down_s}");
            assert!((5.0..=25.0).contains(&up_s), "up_s={up_s}");
        }
    }

    #[test]
    fn shrink_finds_the_single_culprit() {
        let cfg = ChaosConfig::sized(5, 64);
        let evs = plan(&cfg, 64);
        // synthetic failure: any log containing a VmCrash "fails"
        let culprit = |evs: &[ChaosEvent]| {
            evs.iter().any(|e| matches!(e.kind, ChaosKind::VmCrash { .. }))
        };
        assert!(culprit(&evs), "seed 5 plan should contain a VmCrash");
        let min = shrink(&evs, culprit);
        assert_eq!(min.len(), 1, "minimal log should be one event: {min:?}");
        assert!(matches!(min[0].kind, ChaosKind::VmCrash { .. }));
    }

    #[test]
    fn shrink_keeps_event_pairs_that_fail_only_together() {
        let cfg = ChaosConfig::sized(9, 64);
        let evs = plan(&cfg, 64);
        let has = |evs: &[ChaosEvent], f: fn(&ChaosKind) -> bool| evs.iter().any(|e| f(&e.kind));
        let needs_pair = |evs: &[ChaosEvent]| {
            has(evs, |k| matches!(k, ChaosKind::Checkpoint { .. }))
                && has(evs, |k| matches!(k, ChaosKind::Restart { .. }))
        };
        if !needs_pair(&evs) {
            return; // plan happens not to carry both; nothing to shrink
        }
        let min = shrink(&evs, needs_pair);
        assert_eq!(min.len(), 2, "{min:?}");
        assert!(needs_pair(&min));
    }
}

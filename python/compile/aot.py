"""AOT compile path: lower the L2 graphs to HLO **text** artifacts.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's runtime
(xla_extension 0.5.1) rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly.  See /opt/xla-example/README.

Run via `make artifacts`:
    python -m compile.aot --out-dir ../artifacts

Emits one .hlo.txt per (function, shape) plus manifest.json, which the
Rust runtime (rust/src/runtime/artifacts.rs) reads to discover available
executables and their I/O signatures.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I32 = jnp.int32

# Slab shapes (nzl, ny, nx) emitted by default.  Chosen so that one global
# 32x32x32 problem can be decomposed over 1, 2, 4 or 8 worker processes
# (DESIGN.md §4), plus tiny shapes for fast Rust unit tests.
DEFAULT_LU_SHAPES = [
    (32, 32, 32),
    (16, 32, 32),
    (8, 32, 32),
    (4, 32, 32),
    (4, 8, 8),
    (2, 8, 8),
]
# lu_fused (single-proc fast path): (shape, n_iters)
DEFAULT_FUSED = [((32, 32, 32), 4), ((4, 8, 8), 2)]
DEFAULT_DMTCP1_SIZES = [256, 4096]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple, whatever the output arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sig(shapes_dtypes):
    return [{"shape": list(s), "dtype": d} for (s, d) in shapes_dtypes]


def build_entries(lu_shapes, fused, dmtcp1_sizes, omega, h2):
    """Yield (name, fn, arg_specs, input_sig, output_sig, meta)."""
    for (nzl, ny, nx) in lu_shapes:
        slab = ((nzl, ny, nx), "f32")
        plane = ((ny, nx), "f32")
        scalar_i = ((), "i32")
        scalar_f = ((), "f32")

        def sweep(u, lo, hi, f, color, _omega=omega, _h2=h2):
            return model.lu_sweep(u, lo, hi, f, color, omega=_omega, h2=_h2)

        yield (
            f"lu_sweep_{nzl}x{ny}x{nx}", sweep,
            [spec((nzl, ny, nx), F32), spec((ny, nx), F32),
             spec((ny, nx), F32), spec((nzl, ny, nx), F32), spec((), I32)],
            _sig([slab, plane, plane, slab, scalar_i]), _sig([slab]),
            {"kind": "lu_sweep", "shape": [nzl, ny, nx],
             "omega": omega, "h2": h2},
        )

        def resid(u, lo, hi, f, _h2=h2):
            return model.lu_resid(u, lo, hi, f, h2=_h2)

        yield (
            f"lu_resid_{nzl}x{ny}x{nx}", resid,
            [spec((nzl, ny, nx), F32), spec((ny, nx), F32),
             spec((ny, nx), F32), spec((nzl, ny, nx), F32)],
            _sig([slab, plane, plane, slab]), _sig([scalar_f]),
            {"kind": "lu_resid", "shape": [nzl, ny, nx], "h2": h2},
        )

    for ((nzl, ny, nx), n_iters) in fused:
        slab = ((nzl, ny, nx), "f32")

        def fusedfn(u, f, _n=n_iters, _omega=omega, _h2=h2):
            return model.lu_fused(u, f, n_iters=_n, omega=_omega, h2=_h2)

        yield (
            f"lu_fused_{nzl}x{ny}x{nx}_i{n_iters}", fusedfn,
            [spec((nzl, ny, nx), F32), spec((nzl, ny, nx), F32)],
            _sig([slab, slab]), _sig([slab, ((), "f32")]),
            {"kind": "lu_fused", "shape": [nzl, ny, nx],
             "n_iters": n_iters, "omega": omega, "h2": h2},
        )

    for n in dmtcp1_sizes:
        yield (
            f"dmtcp1_{n}", model.dmtcp1_step,
            [spec((n,), F32), spec((), I32)],
            _sig([((n,), "f32"), ((), "i32")]),
            _sig([((n,), "f32"), ((), "i32")]),
            {"kind": "dmtcp1", "n": n},
        )


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower L2 graphs to HLO text")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes only (CI / smoke)")
    ap.add_argument("--omega", type=float, default=model.DEFAULT_OMEGA)
    ap.add_argument("--h2", type=float, default=1.0)
    args = ap.parse_args()

    lu_shapes = [(4, 8, 8), (2, 8, 8)] if args.quick else DEFAULT_LU_SHAPES
    fused = [((4, 8, 8), 2)] if args.quick else DEFAULT_FUSED
    sizes = [256] if args.quick else DEFAULT_DMTCP1_SIZES

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "omega": args.omega, "h2": args.h2,
                "artifacts": []}
    for (name, fn, specs, in_sig, out_sig, meta) in build_entries(
            lu_shapes, fused, sizes, args.omega, args.h2):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as fh:
            fh.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"].append({
            "name": name, "file": fname, "inputs": in_sig,
            "outputs": out_sig, "sha256_16": digest, **meta,
        })
        print(f"  aot: {fname}  ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"  aot: manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()

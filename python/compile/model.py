"""L2 — JAX compute graphs for the checkpointable workloads.

Build-time only: these functions are lowered once by `aot.py` to HLO text
and executed from the Rust runtime (rust/src/runtime) via PJRT.  Python is
never on the request path.

The LU-class workload (DESIGN.md §1) is a domain-decomposed red-black SOR
solver.  Each worker process owns a z-slab `u: (nzl, ny, nx)` plus the
source term `f`.  Halo planes from the z-neighbours are explicit inputs so
the Rust side can perform the exchange (the paper's MPI messaging) between
half-sweeps:

    sweep(color=0) -> exchange halos -> sweep(color=1) -> exchange -> ...

Artifacts emitted per slab shape:
  lu_sweep   (u, halo_lo, halo_hi, f, color) -> (u',)
  lu_resid   (u, halo_lo, halo_hi, f)        -> (sumsq,)
  lu_fused   (u, f; n_iters baked)           -> (u', sumsq)   # 1-proc fast path
  dmtcp1     (x, t)                          -> (x', t')
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import lu_ssor
from .kernels import dmtcp1 as dmtcp1_kernel

DEFAULT_OMEGA = lu_ssor.DEFAULT_OMEGA


def pad_with_halos(u: jax.Array, halo_lo: jax.Array,
                   halo_hi: jax.Array) -> jax.Array:
    """Embed a slab into its (nzl+2, ny+2, nx+2) padded form.

    y/x pads are the global Dirichlet boundary (zero); the z pads carry the
    neighbour halo planes (zero for the boundary processes).
    """
    up = jnp.pad(u, ((1, 1), (1, 1), (1, 1)))
    up = up.at[0, 1:-1, 1:-1].set(halo_lo)
    up = up.at[-1, 1:-1, 1:-1].set(halo_hi)
    return up


def lu_sweep(u: jax.Array, halo_lo: jax.Array, halo_hi: jax.Array,
             f: jax.Array, color: jax.Array, *,
             omega: float = DEFAULT_OMEGA, h2: float = 1.0,
             zoff: int = 0, interpret: bool = True):
    """One half-sweep (one colour) over a slab.  Returns (u',)."""
    u_pad = pad_with_halos(u, halo_lo, halo_hi)
    u2 = lu_ssor.rb_sweep(u_pad, f, color, omega=omega, h2=h2, zoff=zoff,
                          interpret=interpret)
    return (u2,)


def lu_resid(u: jax.Array, halo_lo: jax.Array, halo_hi: jax.Array,
             f: jax.Array, *, h2: float = 1.0, interpret: bool = True):
    """Sum of squared residuals over a slab's interior.  Returns (sumsq,)."""
    u_pad = pad_with_halos(u, halo_lo, halo_hi)
    return (lu_ssor.residual_sumsq(u_pad, f, h2=h2, interpret=interpret),)


def lu_fused(u: jax.Array, f: jax.Array, *, n_iters: int = 1,
             omega: float = DEFAULT_OMEGA, h2: float = 1.0,
             interpret: bool = True):
    """Single-process fast path: `n_iters` full (red+black) sweeps plus the
    final residual, fused into one HLO via lax.scan (L2 perf: amortizes
    PJRT dispatch; no host round-trip between colours — valid only when
    there are no neighbours to exchange with).  Returns (u', sumsq).
    """
    zeros = jnp.zeros(u.shape[1:], u.dtype)

    def body(uu, _):
        for color in (0, 1):
            (uu,) = lu_sweep(uu, zeros, zeros, f,
                             jnp.int32(color), omega=omega, h2=h2,
                             interpret=interpret)
        return uu, None

    u2, _ = jax.lax.scan(body, u, None, length=n_iters)
    (ss,) = lu_resid(u2, zeros, zeros, f, h2=h2, interpret=interpret)
    return (u2, ss)


def dmtcp1_step(x: jax.Array, t: jax.Array, *, interpret: bool = True):
    """Lightweight-app step.  Returns (x', t')."""
    x2, t2 = dmtcp1_kernel.dmtcp1_step(x, t, interpret=interpret)
    return (x2, t2)


# ---------------------------------------------------------------------------
# Pure-python driver used by tests (and to cross-check the Rust driver):
# runs P slabs with explicit halo exchange, exactly the protocol the Rust
# coordinator follows.
# ---------------------------------------------------------------------------

def decompose(nz: int, nprocs: int) -> list[int]:
    """Split nz planes into nprocs equal slabs (nz % nprocs == 0, even slabs
    so every slab starts at an even global z and zoff can be baked as 0)."""
    if nz % nprocs != 0:
        raise ValueError(f"nz={nz} not divisible by nprocs={nprocs}")
    nzl = nz // nprocs
    if nzl % 2 != 0:
        raise ValueError(f"slab height {nzl} must be even (parity baking)")
    return [nzl] * nprocs


def multi_proc_solve(u0: jax.Array, f: jax.Array, nprocs: int,
                     n_iters: int, *, omega: float = DEFAULT_OMEGA,
                     h2: float = 1.0, interpret: bool = True):
    """Reference distributed driver: returns (u_final, residual history)."""
    nz = u0.shape[0]
    nzl = decompose(nz, nprocs)[0]
    slabs = [u0[i * nzl:(i + 1) * nzl] for i in range(nprocs)]
    fs = [f[i * nzl:(i + 1) * nzl] for i in range(nprocs)]
    zeros = jnp.zeros(u0.shape[1:], u0.dtype)

    def halos(i):
        lo = slabs[i - 1][-1] if i > 0 else zeros
        hi = slabs[i + 1][0] if i < nprocs - 1 else zeros
        return lo, hi

    history = []
    for _ in range(n_iters):
        for color in (0, 1):
            new = []
            for i in range(nprocs):
                lo, hi = halos(i)
                (s2,) = lu_sweep(slabs[i], lo, hi, fs[i],
                                 jnp.int32(color), omega=omega, h2=h2,
                                 interpret=interpret)
                new.append(s2)
            slabs = new
        ss = 0.0
        for i in range(nprocs):
            lo, hi = halos(i)
            (p,) = lu_resid(slabs[i], lo, hi, fs[i], h2=h2,
                            interpret=interpret)
            ss = ss + p
        history.append(float(jnp.sqrt(ss)))
    return jnp.concatenate(slabs, axis=0), history


def make_problem(nz: int, ny: int, nx: int, seed: int = 7):
    """Deterministic synthetic Poisson problem.  The Rust side reconstructs
    the identical arrays (splitmix64-based, see rust/src/workloads/lu.rs),
    so we use the same integer-hash construction instead of jax.random."""
    total = nz * ny * nx
    idx = jnp.arange(total, dtype=jnp.uint32)

    def h(x, salt):
        x = (x ^ jnp.uint32(salt)) * jnp.uint32(0x9E3779B9)
        x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
        x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
        return (x ^ (x >> 16)).astype(jnp.float32) / jnp.float32(2**32)

    u0 = (0.2 * (h(idx, seed) - 0.5)).reshape(nz, ny, nx)
    f = (2.0 * (h(idx, seed + 1) - 0.5)).reshape(nz, ny, nx)
    return u0, f

"""L1 — Pallas kernel for the `dmtcp1` lightweight application.

The paper's resource-consumption and migration experiments (§7.2, §7.3.2)
use `dmtcp1`, a single-process lightweight app from the DMTCP test suite.
Our analog carries a small float vector plus a step counter; the per-step
update is a trivially cheap elementwise decay+oscillation, expressed as a
Pallas kernel so that even the "lightweight" app exercises the full
L1→L2→HLO→PJRT path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_DECAY = 0.999


def _dmtcp1_kernel(x_ref, t_ref, ox_ref, ot_ref, *, decay: float):
    t = t_ref[0]
    x = x_ref[...]
    n = x.shape[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (n,), 0).astype(jnp.float32)
    phase = t.astype(jnp.float32) + idx
    ox_ref[...] = decay * x + 0.001 * jnp.sin(0.01 * phase)
    ot_ref[0] = t + 1


def dmtcp1_step(x: jax.Array, t: jax.Array, *, decay: float = DEFAULT_DECAY,
                interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """One step of the lightweight app: (x, t) -> (x', t+1)."""
    n = x.shape[0]
    t1 = jnp.asarray(t, jnp.int32).reshape(1)
    ox, ot = pl.pallas_call(
        functools.partial(_dmtcp1_kernel, decay=decay),
        in_specs=[
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(x, t1)
    return ox, ot[0]

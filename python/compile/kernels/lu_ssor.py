"""L1 — Pallas kernel: red-black SOR sweep for the LU-class workload.

The paper's scalability workload is NAS MPI LU (class C), an SSOR solver for
3-D Navier-Stokes.  We reproduce its *systems role* (long-running, domain-
decomposed iterative FP compute with halo exchange and per-process state
that shrinks as 1/nprocs) with a red-black SOR relaxation of a 7-point
Poisson stencil on a 3-D grid — the parallel (colourable) variant of SSOR.

TPU adaptation (DESIGN.md §2): the sweep is expressed over z-planes.  Each
pallas grid instance pulls three adjacent padded planes (z-1, z, z+1) from
HBM into VMEM via three BlockSpec views of the same padded array, updates
the interior cells of one colour, and writes one unpadded plane back.  The
(ny, nx) plane is the vector dimension (lanes along x); per-instance VMEM
footprint is 3*(ny+2)*(nx+2)*4 B for u plus (ny*nx)*4 B each for f and the
output — documented in DESIGN.md §8.

Correctness is validated under interpret=True against kernels/ref.py
(real-TPU lowering emits a Mosaic custom-call the CPU plugin cannot run).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# SOR relaxation factor used across the repo (tests override it).
DEFAULT_OMEGA = 1.2


def _rb_plane_kernel(color_ref, lo_ref, mid_ref, hi_ref, f_ref, out_ref, *,
                     omega: float, h2: float, zoff: int):
    """Update one z-plane's cells of one colour.

    color_ref : (1, 1) int32 — the colour (0 or 1) being swept.
    lo/mid/hi : (1, ny+2, nx+2) padded planes z-1, z, z+1 (global z-pad).
    f_ref     : (1, ny, nx) source term for this plane.
    out_ref   : (1, ny, nx) updated plane (interior only).
    """
    z = pl.program_id(0)
    color = color_ref[0, 0]

    mid = mid_ref[0]                       # (ny+2, nx+2)
    u = mid[1:-1, 1:-1]                    # (ny, nx) current interior
    north = mid[:-2, 1:-1]
    south = mid[2:, 1:-1]
    west = mid[1:-1, :-2]
    east = mid[1:-1, 2:]
    down = lo_ref[0][1:-1, 1:-1]
    up = hi_ref[0][1:-1, 1:-1]
    f = f_ref[0]

    # Gauss-Seidel value for every interior cell of this plane.
    gs = (north + south + west + east + down + up - h2 * f) * (1.0 / 6.0)
    new = (1.0 - omega) * u + omega * gs

    ny, nx = f.shape
    iy = jax.lax.broadcasted_iota(jnp.int32, (ny, nx), 0)
    ix = jax.lax.broadcasted_iota(jnp.int32, (ny, nx), 1)
    # Global parity of the cell: slab offset zoff is baked in at lowering
    # time; z is the local plane index.
    parity = (z + zoff + iy + ix) % 2
    mask = parity == color

    out_ref[0] = jnp.where(mask, new, u)


def rb_sweep(u_pad: jax.Array, f: jax.Array, color: jax.Array, *,
             omega: float = DEFAULT_OMEGA, h2: float = 1.0,
             zoff: int = 0, interpret: bool = True) -> jax.Array:
    """One red-black half-sweep over a padded slab.

    u_pad : (nzl+2, ny+2, nx+2) slab with halo planes already applied
            (z-halos from neighbour processes, y/x-halos are the global
            Dirichlet boundary).
    f     : (nzl, ny, nx) source term.
    color : scalar int32 (0 or 1) — which colour to update.

    Returns the updated interior slab (nzl, ny, nx).
    """
    nzp, nyp, nxp = u_pad.shape
    nzl, ny, nx = nzp - 2, nyp - 2, nxp - 2
    if f.shape != (nzl, ny, nx):
        raise ValueError(f"f shape {f.shape} != {(nzl, ny, nx)}")

    kernel = functools.partial(_rb_plane_kernel, omega=omega, h2=h2,
                               zoff=zoff)
    color2d = jnp.asarray(color, jnp.int32).reshape(1, 1)

    plane = (1, nyp, nxp)
    return pl.pallas_call(
        kernel,
        grid=(nzl,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda z: (0, 0)),        # colour scalar
            pl.BlockSpec(plane, lambda z: (z, 0, 0)),      # plane z-1
            pl.BlockSpec(plane, lambda z: (z + 1, 0, 0)),  # plane z
            pl.BlockSpec(plane, lambda z: (z + 2, 0, 0)),  # plane z+1
            pl.BlockSpec((1, ny, nx), lambda z: (z, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ny, nx), lambda z: (z, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nzl, ny, nx), u_pad.dtype),
        interpret=interpret,
    )(color2d, u_pad, u_pad, u_pad, f)


def _resid_plane_kernel(lo_ref, mid_ref, hi_ref, f_ref, out_ref, *,
                        h2: float):
    """Per-plane squared residual of the 7-point operator: r = A u - f."""
    mid = mid_ref[0]
    u = mid[1:-1, 1:-1]
    lap = (mid[:-2, 1:-1] + mid[2:, 1:-1] + mid[1:-1, :-2] + mid[1:-1, 2:]
           + lo_ref[0][1:-1, 1:-1] + hi_ref[0][1:-1, 1:-1] - 6.0 * u)
    r = lap * (1.0 / h2) - f_ref[0]
    out_ref[0, 0] = jnp.sum(r * r)


def residual_sumsq(u_pad: jax.Array, f: jax.Array, *, h2: float = 1.0,
                   interpret: bool = True) -> jax.Array:
    """Sum of squared residuals over the slab interior (scalar f32).

    The per-plane partial sums are produced by a pallas kernel over the
    same three-plane VMEM schedule as the sweep; the final reduction over
    planes happens in jnp (L2) so the whole thing fuses into one HLO.
    """
    nzp, nyp, nxp = u_pad.shape
    nzl, ny, nx = nzp - 2, nyp - 2, nxp - 2
    plane = (1, nyp, nxp)
    partial = pl.pallas_call(
        functools.partial(_resid_plane_kernel, h2=h2),
        grid=(nzl,),
        in_specs=[
            pl.BlockSpec(plane, lambda z: (z, 0, 0)),
            pl.BlockSpec(plane, lambda z: (z + 1, 0, 0)),
            pl.BlockSpec(plane, lambda z: (z + 2, 0, 0)),
            pl.BlockSpec((1, ny, nx), lambda z: (z, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda z: (z, 0)),
        out_shape=jax.ShapeDtypeStruct((nzl, 1), u_pad.dtype),
        interpret=interpret,
    )(u_pad, u_pad, u_pad, f)
    return jnp.sum(partial)

"""Pure-jnp oracles for the Pallas kernels (build-time correctness only).

Every kernel in this package has a reference implementation here written
with plain jax.numpy, no pallas.  pytest (and hypothesis sweeps) assert
allclose between kernel and oracle across shapes, colours and relaxation
factors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rb_sweep_ref(u_pad: jax.Array, f: jax.Array, color, *,
                 omega: float = 1.2, h2: float = 1.0,
                 zoff: int = 0) -> jax.Array:
    """Reference red-black SOR half-sweep.  Same contract as lu_ssor.rb_sweep."""
    nzl, ny, nx = f.shape
    u = u_pad[1:-1, 1:-1, 1:-1]
    nbr = (u_pad[:-2, 1:-1, 1:-1] + u_pad[2:, 1:-1, 1:-1]
           + u_pad[1:-1, :-2, 1:-1] + u_pad[1:-1, 2:, 1:-1]
           + u_pad[1:-1, 1:-1, :-2] + u_pad[1:-1, 1:-1, 2:])
    gs = (nbr - h2 * f) / 6.0
    new = (1.0 - omega) * u + omega * gs

    iz = jax.lax.broadcasted_iota(jnp.int32, (nzl, ny, nx), 0)
    iy = jax.lax.broadcasted_iota(jnp.int32, (nzl, ny, nx), 1)
    ix = jax.lax.broadcasted_iota(jnp.int32, (nzl, ny, nx), 2)
    mask = (iz + zoff + iy + ix) % 2 == jnp.asarray(color, jnp.int32)
    return jnp.where(mask, new, u)


def residual_sumsq_ref(u_pad: jax.Array, f: jax.Array, *,
                       h2: float = 1.0) -> jax.Array:
    """Reference sum of squared residuals of the 7-point operator."""
    u = u_pad[1:-1, 1:-1, 1:-1]
    lap = (u_pad[:-2, 1:-1, 1:-1] + u_pad[2:, 1:-1, 1:-1]
           + u_pad[1:-1, :-2, 1:-1] + u_pad[1:-1, 2:, 1:-1]
           + u_pad[1:-1, 1:-1, :-2] + u_pad[1:-1, 1:-1, 2:] - 6.0 * u)
    r = lap / h2 - f
    return jnp.sum(r * r)


def dmtcp1_step_ref(x: jax.Array, t: jax.Array, *,
                    decay: float = 0.999) -> tuple[jax.Array, jax.Array]:
    """Reference for the dmtcp1 lightweight-app step."""
    phase = (t.astype(jnp.float32) + jnp.arange(x.shape[0], dtype=jnp.float32))
    x2 = decay * x + 0.001 * jnp.sin(0.01 * phase)
    return x2, t + 1

"""AOT path: every artifact entry lowers to parseable HLO text with the
declared signature, and the manifest is consistent."""

import json
import os
import tempfile

import jax
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_build_entries_quick_signatures():
    entries = list(aot.build_entries([(2, 8, 8)], [((2, 8, 8), 1)], [64],
                                     omega=1.2, h2=1.0))
    names = [e[0] for e in entries]
    assert names == ["lu_sweep_2x8x8", "lu_resid_2x8x8",
                     "lu_fused_2x8x8_i1", "dmtcp1_64"]
    for (_name, _fn, specs, in_sig, _out, _meta) in entries:
        assert len(specs) == len(in_sig)
        for s, d in zip(specs, in_sig):
            assert list(s.shape) == d["shape"]


def test_lowering_produces_hlo_text():
    entries = list(aot.build_entries([(2, 4, 4)], [], [32],
                                     omega=1.2, h2=1.0))
    for (name, fn, specs, _in, _out, _meta) in entries:
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_main_quick_writes_manifest(monkeypatch):
    with tempfile.TemporaryDirectory() as d:
        monkeypatch.setattr(
            "sys.argv", ["aot", "--quick", "--out-dir", d])
        aot.main()
        with open(os.path.join(d, "manifest.json")) as fh:
            man = json.load(fh)
        assert man["version"] == 1
        assert len(man["artifacts"]) >= 5
        for a in man["artifacts"]:
            path = os.path.join(d, a["file"])
            assert os.path.exists(path), a["file"]
            with open(path) as fh:
                assert fh.read().startswith("HloModule")
            assert a["inputs"] and a["outputs"]


def test_sweep_hlo_declares_expected_parameters():
    """Structural check of the emitted HLO text: entry computation takes the
    five declared parameters with the right shapes and returns a 1-tuple.
    (The numeric round-trip through PJRT is proven on the Rust side by
    rust/tests/runtime_roundtrip.rs, which executes these artifacts and
    compares against values generated here.)"""
    entries = [e for e in aot.build_entries([(2, 4, 4)], [], [],
                                            omega=1.2, h2=1.0)
               if e[0].startswith("lu_sweep")]
    (_name, fn, specs, in_sig, out_sig, _meta) = entries[0]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    entry = lines[start:]
    params = [l for l in entry if "parameter(" in l]
    assert sum("f32[2,4,4]" in p for p in params) == 2  # u and f
    assert sum("f32[4,4]{" in p for p in params) == 2   # the two halos
    assert sum("s32[]" in p for p in params) == 1       # colour
    # return_tuple=True -> root is a tuple of one f32[2,4,4]
    root = [l for l in entry if "ROOT" in l]
    assert len(root) == 1 and "(f32[2,4,4]" in root[0]
    assert len(in_sig) == 5 and len(out_sig) == 1

"""L1 structural checks (DESIGN.md §2/§8): VMEM budget of the BlockSpec
schedule, parity coverage, and solver-grade numerical behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import lu_ssor, ref

jax.config.update("jax_platform_name", "cpu")

VMEM_BYTES = 16 * 1024 * 1024  # v4/v5 per-core VMEM


def vmem_per_instance(nzl, ny, nx):
    """Bytes resident per pallas grid instance under the three-plane
    schedule: 3 padded u planes in + f plane in + output plane."""
    padded_plane = (ny + 2) * (nx + 2) * 4
    plane = ny * nx * 4
    return 3 * padded_plane + 2 * plane


@pytest.mark.parametrize("shape", [(32, 32, 32), (16, 128, 128), (8, 256, 256)])
def test_vmem_budget_holds(shape):
    nzl, ny, nx = shape
    assert vmem_per_instance(nzl, ny, nx) < 0.25 * VMEM_BYTES, (
        "per-instance footprint must leave room for double buffering"
    )


def test_vmem_scales_with_plane_not_slab():
    # the z-plane grid means VMEM is independent of slab height
    assert vmem_per_instance(2, 64, 64) == vmem_per_instance(64, 64, 64)


def test_lane_dimension_is_contiguous():
    # x (fastest-varying) is the lane dimension: row-major layout means
    # stride 1 in x for every operand the kernel touches
    u = jnp.zeros((4, 8, 16), jnp.float32)
    assert u.shape[-1] == 16  # last dim = x by construction in model.py


@settings(max_examples=10, deadline=None)
@given(
    nzl=st.sampled_from([2, 4]),
    ny=st.sampled_from([4, 8]),
    nx=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_full_step_reduces_residual(nzl, ny, nx, seed):
    """A red+black sweep pair must not increase the residual for the SPD
    Poisson operator with omega in (0,2) — solver-grade sanity across
    random problems."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.uniform(-1, 1, (nzl, ny, nx)).astype(np.float32))
    f = jnp.asarray(rng.uniform(-1, 1, (nzl, ny, nx)).astype(np.float32))
    zeros = jnp.zeros((ny, nx), jnp.float32)
    (r0,) = model.lu_resid(u, zeros, zeros, f)
    for color in (0, 1):
        (u,) = model.lu_sweep(u, zeros, zeros, f, jnp.int32(color))
    (r1,) = model.lu_resid(u, zeros, zeros, f)
    assert float(r1) <= float(r0) * 1.0 + 1e-5


def test_sweep_is_idempotent_per_color():
    """Sweeping the same colour twice with identical halos equals
    sweeping once (the second pass sees identical neighbour values for
    cells of that colour)."""
    u_pad = jnp.asarray(
        np.random.default_rng(3).uniform(-1, 1, (5, 7, 7)).astype(np.float32)
    )
    f = jnp.asarray(np.random.default_rng(4).uniform(-1, 1, (3, 5, 5)).astype(np.float32))
    # omega=1 (pure Gauss-Seidel): the update depends only on the
    # neighbours, which a same-colour repeat leaves untouched
    once = lu_ssor.rb_sweep(u_pad, f, jnp.int32(0), omega=1.0)
    # re-embed and sweep color 0 again: neighbours (colour 1) unchanged
    up2 = u_pad.at[1:-1, 1:-1, 1:-1].set(once)
    twice = lu_ssor.rb_sweep(up2, f, jnp.int32(0), omega=1.0)
    np.testing.assert_allclose(once, twice, rtol=1e-5, atol=1e-6)


def test_residual_zero_iff_exact_solution():
    rng = np.random.default_rng(9)
    u_pad = jnp.asarray(rng.uniform(-1, 1, (6, 6, 6)).astype(np.float32))
    up = u_pad
    lap = (up[:-2, 1:-1, 1:-1] + up[2:, 1:-1, 1:-1] + up[1:-1, :-2, 1:-1]
           + up[1:-1, 2:, 1:-1] + up[1:-1, 1:-1, :-2] + up[1:-1, 1:-1, 2:]
           - 6.0 * up[1:-1, 1:-1, 1:-1])
    got = lu_ssor.residual_sumsq(u_pad, lap)
    assert float(got) < 1e-8
    # perturb one cell -> strictly positive residual
    bad = lap.at[1, 1, 1].add(1.0)
    got2 = lu_ssor.residual_sumsq(u_pad, bad)
    assert float(got2) > 0.5


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_reference_and_kernel_agree_after_many_sweeps(seed):
    """Accumulated drift check: 10 full iterations through the kernel
    stay within f32 tolerance of 10 through the oracle."""
    rng = np.random.default_rng(seed)
    u_k = jnp.asarray(rng.uniform(-0.1, 0.1, (4, 6, 6)).astype(np.float32))
    f = jnp.asarray(rng.uniform(-1, 1, (4, 6, 6)).astype(np.float32))
    u_r = u_k
    zeros = jnp.zeros((6, 6), jnp.float32)
    for _ in range(10):
        for color in (0, 1):
            (u_k,) = model.lu_sweep(u_k, zeros, zeros, f, jnp.int32(color))
            u_pad = model.pad_with_halos(u_r, zeros, zeros)
            u_r = ref.rb_sweep_ref(u_pad, f, color)
    np.testing.assert_allclose(u_k, u_r, rtol=1e-4, atol=1e-5)

"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Includes a hypothesis sweep over slab shapes, colours and relaxation
factors, per the repro mandate (hypothesis substitutes for shape/dtype
fuzzing of the kernel).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lu_ssor, ref
from compile.kernels import dmtcp1 as dmtcp1_kernel

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, lo=-1.0, hi=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, shape).astype(np.float32))


def pad_slab(u, halo_lo, halo_hi):
    up = jnp.pad(u, ((1, 1), (1, 1), (1, 1)))
    up = up.at[0, 1:-1, 1:-1].set(halo_lo)
    up = up.at[-1, 1:-1, 1:-1].set(halo_hi)
    return up


SHAPES = [(2, 4, 4), (4, 8, 8), (3, 5, 7), (6, 4, 16), (1, 8, 8)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("color", [0, 1])
def test_rb_sweep_matches_ref(shape, color):
    nzl, ny, nx = shape
    u_pad = rand((nzl + 2, ny + 2, nx + 2), seed=1)
    f = rand(shape, seed=2)
    got = lu_ssor.rb_sweep(u_pad, f, jnp.int32(color))
    want = ref.rb_sweep_ref(u_pad, f, color)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_residual_matches_ref(shape):
    nzl, ny, nx = shape
    u_pad = rand((nzl + 2, ny + 2, nx + 2), seed=3)
    f = rand(shape, seed=4)
    got = lu_ssor.residual_sumsq(u_pad, f)
    want = ref.residual_sumsq_ref(u_pad, f)
    np.testing.assert_allclose(got, want, rtol=2e-5)


@pytest.mark.parametrize("zoff", [0, 1, 2, 5])
def test_zoff_shifts_parity(zoff):
    """Baked slab offset must shift the update mask exactly."""
    shape = (3, 4, 4)
    u_pad = rand((5, 6, 6), seed=5)
    f = rand(shape, seed=6)
    got = lu_ssor.rb_sweep(u_pad, f, jnp.int32(0), zoff=zoff)
    want = ref.rb_sweep_ref(u_pad, f, 0, zoff=zoff)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_two_colors_cover_all_cells():
    """After sweeping both colours every interior cell must change (generic
    data), and cells untouched by colour c must be exactly the input."""
    shape = (4, 6, 6)
    u_pad = rand((6, 8, 8), seed=7)
    f = rand(shape, seed=8) + 2.0  # keep updates away from fixed points
    u = u_pad[1:-1, 1:-1, 1:-1]
    r0 = lu_ssor.rb_sweep(u_pad, f, jnp.int32(0))
    r1 = lu_ssor.rb_sweep(u_pad, f, jnp.int32(1))
    changed0 = np.asarray(r0 != u)
    changed1 = np.asarray(r1 != u)
    assert not np.any(changed0 & changed1), "colours must be disjoint"
    # every cell belongs to exactly one colour's mask
    iz, iy, ix = np.indices(shape)
    mask0 = (iz + iy + ix) % 2 == 0
    np.testing.assert_array_equal(np.asarray(r0)[~mask0], np.asarray(u)[~mask0])
    np.testing.assert_array_equal(np.asarray(r1)[mask0], np.asarray(u)[mask0])


def test_sor_fixed_point():
    """If u already solves A u = f exactly, a sweep must not move it."""
    shape = (4, 4, 4)
    u_pad = rand((6, 6, 6), seed=9)
    # compute f := A u so that the residual is exactly zero
    up = u_pad
    lap = (up[:-2, 1:-1, 1:-1] + up[2:, 1:-1, 1:-1] + up[1:-1, :-2, 1:-1]
           + up[1:-1, 2:, 1:-1] + up[1:-1, 1:-1, :-2] + up[1:-1, 1:-1, 2:]
           - 6.0 * up[1:-1, 1:-1, 1:-1])
    f = lap  # h2 = 1
    for color in (0, 1):
        got = lu_ssor.rb_sweep(u_pad, f, jnp.int32(color), omega=1.5)
        np.testing.assert_allclose(got, u_pad[1:-1, 1:-1, 1:-1],
                                   rtol=1e-5, atol=1e-6)
    ss = lu_ssor.residual_sumsq(u_pad, f)
    assert float(ss) < 1e-8


@settings(max_examples=25, deadline=None)
@given(
    nzl=st.integers(1, 5), ny=st.integers(2, 9), nx=st.integers(2, 9),
    color=st.integers(0, 1),
    omega=st.floats(0.5, 1.9), seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep_matches_ref(nzl, ny, nx, color, omega, seed):
    u_pad = rand((nzl + 2, ny + 2, nx + 2), seed=seed)
    f = rand((nzl, ny, nx), seed=seed + 1)
    got = lu_ssor.rb_sweep(u_pad, f, jnp.int32(color), omega=omega)
    want = ref.rb_sweep_ref(u_pad, f, color, omega=omega)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 512), t=st.integers(0, 10_000),
       seed=st.integers(0, 2**31 - 1))
def test_hypothesis_dmtcp1_matches_ref(n, t, seed):
    x = rand((n,), seed=seed)
    gx, gt = dmtcp1_kernel.dmtcp1_step(x, jnp.int32(t))
    wx, wt = ref.dmtcp1_step_ref(x, jnp.int32(t))
    np.testing.assert_allclose(gx, wx, rtol=1e-6, atol=1e-7)
    assert int(gt) == int(wt) == t + 1

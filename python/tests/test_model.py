"""L2 correctness: solver convergence, domain-decomposition equivalence,
fused fast path, problem-generator determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def global_resid(u, f):
    zeros_pad = model.pad_with_halos(u, jnp.zeros(u.shape[1:]),
                                     jnp.zeros(u.shape[1:]))
    return float(jnp.sqrt(ref.residual_sumsq_ref(zeros_pad, f)))


def test_solver_converges_single_proc():
    u0, f = model.make_problem(8, 8, 8)
    u, hist = model.multi_proc_solve(u0, f, nprocs=1, n_iters=30)
    assert hist[-1] < 0.05 * hist[0], f"no convergence: {hist[0]} -> {hist[-1]}"
    # monotone (SOR on SPD system with omega in (0,2) contracts in energy
    # norm; l2 residual is near-monotone — allow tiny wiggle)
    for a, b in zip(hist, hist[1:]):
        assert b < a * 1.05


@pytest.mark.parametrize("nprocs", [2, 4])
def test_decomposition_matches_single_proc(nprocs):
    """P slabs with halo exchange == 1 proc, bitwise up to float assoc."""
    u0, f = model.make_problem(8, 8, 8)
    u1, h1 = model.multi_proc_solve(u0, f, nprocs=1, n_iters=5)
    up, hp = model.multi_proc_solve(u0, f, nprocs=nprocs, n_iters=5)
    np.testing.assert_allclose(u1, up, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h1, hp, rtol=1e-4)


def test_fused_matches_stepwise():
    u0, f = model.make_problem(4, 8, 8)
    (uf, ss) = model.lu_fused(u0, f, n_iters=3)
    u, hist = model.multi_proc_solve(u0, f, nprocs=1, n_iters=3)
    np.testing.assert_allclose(uf, u, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(jnp.sqrt(ss)), hist[-1], rtol=1e-4)


def test_decompose_validation():
    assert model.decompose(32, 4) == [8, 8, 8, 8]
    with pytest.raises(ValueError):
        model.decompose(10, 3)


def test_decompose_even_slabs():
    assert model.decompose(12, 6) == [2] * 6
    with pytest.raises(ValueError):
        model.decompose(12, 4)  # 12/4 = 3, odd slab -> parity baking breaks


def test_make_problem_deterministic():
    a0, af = model.make_problem(4, 4, 4, seed=7)
    b0, bf = model.make_problem(4, 4, 4, seed=7)
    np.testing.assert_array_equal(a0, b0)
    np.testing.assert_array_equal(af, bf)
    c0, _ = model.make_problem(4, 4, 4, seed=8)
    assert not np.array_equal(a0, c0)
    # values bounded as documented
    assert float(jnp.max(jnp.abs(a0))) <= 0.1 + 1e-6
    assert float(jnp.max(jnp.abs(af))) <= 1.0 + 1e-6


def test_halo_padding_contract():
    u = jnp.arange(2 * 3 * 3, dtype=jnp.float32).reshape(2, 3, 3)
    lo = jnp.full((3, 3), -1.0)
    hi = jnp.full((3, 3), -2.0)
    up = model.pad_with_halos(u, lo, hi)
    assert up.shape == (4, 5, 5)
    np.testing.assert_array_equal(up[0, 1:-1, 1:-1], lo)
    np.testing.assert_array_equal(up[-1, 1:-1, 1:-1], hi)
    np.testing.assert_array_equal(up[1:-1, 1:-1, 1:-1], u)
    assert float(jnp.sum(jnp.abs(up[:, 0, :]))) == 0.0
    assert float(jnp.sum(jnp.abs(up[:, :, -1]))) == 0.0
